//! Property-based tests over the platform's core invariants, using the
//! in-tree seeded property kit (`ddr4bench::testkit`; reproduce failures
//! with `DDR4BENCH_PT_SEED=<seed>`).
//!
//! Invariants (DESIGN.md §7):
//! - address mapping is bijective for every mapping policy;
//! - AXI WRAP bursts stay inside their container and visit each slot once;
//! - the DDR4 device never admits a timing-illegal command under random
//!   command streams (`can_issue` ⊢ `earliest_issue`);
//! - FR-FCFS never loses or duplicates requests (conservation), and
//!   same-address requests never reorder;
//! - batch counters conserve: issued = completed, bytes = txns × size;
//! - telemetry sampling is observation-only: every measured observable
//!   is bit-identical with the sampler armed or absent;
//! - pattern configs round-trip through the host-protocol CFG syntax;
//! - PRBS expansion is deterministic and never produces a zero word.

use ddr4bench::config::{
    format_pattern_config, parse_pattern_config, AddrMode, BurstKind, BurstSpec,
    ControllerParams, DataPattern, DesignConfig, EngineKind, OpMix, PatternConfig, SchedKind,
    Signaling, SpeedBin,
};
use ddr4bench::controller::{MemController, MemRequest};
use ddr4bench::ddr4::{Cmd, DdrDevice, DramGeometry, MappingPolicy, TimingParams};
use ddr4bench::platform::Platform;
use ddr4bench::rng::SplitMix64;
use ddr4bench::testkit::{check, check_shrink};
use ddr4bench::trafficgen::payload;

/// Every mapping policy the engine can express: the four built-ins plus
/// custom bit orders (including XOR-hashed ones).
fn all_policies() -> Vec<MappingPolicy> {
    let mut v = MappingPolicy::builtins().to_vec();
    for custom in ["RoBaBgCo", "CoRoBaBg", "BgRoBaCo", "XorRoBaBgCo", "XorRoBgBaCo"] {
        v.push(MappingPolicy::parse(custom).expect(custom));
    }
    v
}

/// Geometries the bijectivity sweep covers: the proFPGA board plus a
/// small and an asymmetric (4-group) variant.
fn all_geometries() -> Vec<DramGeometry> {
    let board = DramGeometry::profpga_board();
    let mut small = board;
    small.rows = 1 << 12;
    small.cols = 256;
    let mut wide = board;
    wide.bank_groups = 4;
    wide.banks_per_group = 2;
    vec![board, small, wide]
}

#[test]
fn prop_address_mapping_bijective() {
    for mapping in all_policies() {
        for mut geo in all_geometries() {
            geo.mapping = mapping;
            assert!(geo.validate().is_ok());
            check(
                &format!("addr mapping bijective {mapping} rows={}", geo.rows),
                2000,
                |rng| rng.below(geo.capacity_bytes()),
                |&addr| {
                    let dec = geo.decode(addr);
                    let enc = geo.encode(dec);
                    if enc != addr & !63 {
                        return Err(format!("{addr:#x} -> {dec:?} -> {enc:#x}"));
                    }
                    if dec.bank >= geo.banks() || dec.row >= geo.rows || dec.col >= geo.cols {
                        return Err(format!("decoded fields out of range: {dec:?}"));
                    }
                    let coord = geo.decode_coord(addr);
                    if coord.to_flat(geo.banks_per_group) != dec {
                        return Err(format!("coord/flat disagree: {coord:?} vs {dec:?}"));
                    }
                    if geo.encode_coord(coord) != addr & !63 {
                        return Err(format!("encode_coord breaks round trip at {addr:#x}"));
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn prop_bank_conflict_pins_one_bank_under_every_mapping_policy() {
    for mapping in all_policies() {
        let mut geo = DramGeometry::profpga_board();
        geo.mapping = mapping;
        check(
            &format!("bank conflict pinned under {mapping}"),
            40,
            |rng| rng.next_u64() >> 1,
            |&seed| {
                let mode = AddrMode::BankConflict { seed };
                let spec = BurstSpec { len: 1, kind: BurstKind::Incr };
                let mut g =
                    ddr4bench::trafficgen::AddrGen::new(&mode, 0, 256 << 20, spec, 32, &geo);
                let mut prev: Option<ddr4bench::ddr4::DramAddr> = None;
                for i in 0..96 {
                    let a = g.next_addr();
                    if a >= 256 << 20 {
                        return Err(format!("{mapping}: addr {a:#x} escapes the region"));
                    }
                    let d = geo.decode(a);
                    if let Some(p) = prev {
                        if d.bank != p.bank {
                            return Err(format!(
                                "{mapping}: bank drifted {} -> {} at txn {i}",
                                p.bank, d.bank
                            ));
                        }
                        if d.row == p.row {
                            return Err(format!("{mapping}: row {} repeated", d.row));
                        }
                    }
                    prev = Some(d);
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_distinct_bursts_decode_distinct() {
    let geo = DramGeometry::profpga_board();
    check(
        "distinct bursts decode to distinct locations",
        3000,
        |rng| (rng.below(1 << 26) * 64, rng.below(1 << 26) * 64),
        |&(a, b)| {
            if a != b && geo.decode(a) == geo.decode(b) {
                return Err(format!("{a:#x} and {b:#x} collide at {:?}", geo.decode(a)));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_wrap_burst_in_container_each_slot_once() {
    check(
        "WRAP bursts visit each container slot once",
        2000,
        |rng| {
            let len = [2u32, 4, 8, 16][rng.below(4) as usize];
            let beat = 32u32;
            let addr = rng.below(1 << 30) & !(beat as u64 - 1);
            (addr, len)
        },
        |&(addr, len)| {
            let spec = BurstSpec { len, kind: BurstKind::Wrap };
            let addrs = ddr4bench::axi::beat_addresses(addr, spec, 32);
            let container = len as u64 * 32;
            let base = addr / container * container;
            let mut seen = std::collections::HashSet::new();
            for a in &addrs {
                if *a < base || *a >= base + container {
                    return Err(format!("beat {a:#x} escapes container [{base:#x}, +{container})"));
                }
                if !seen.insert(*a) {
                    return Err(format!("slot {a:#x} visited twice"));
                }
            }
            if seen.len() != len as usize {
                return Err("not all slots visited".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_device_never_admits_illegal_command() {
    // Random command streams issued at exactly earliest_issue: every
    // accepted command must satisfy can_issue, and issuing at
    // earliest-1 must be rejected (when > current time floor).
    check(
        "device timing legality",
        60,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = SplitMix64::new(seed);
            let mut dev = DdrDevice::new(
                TimingParams::for_bin(SpeedBin::Ddr4_2400),
                DramGeometry::profpga_board(),
            );
            let mut now = 0u64;
            for step in 0..400 {
                let bank = rng.below(8) as u32;
                let cmd = match rng.below(4) {
                    0 => Cmd::Act { bank, row: rng.below(1024) as u32 },
                    1 => Cmd::Pre { bank },
                    2 => Cmd::Rd { bank, col: (rng.below(128) * 8) as u32, auto_pre: false },
                    _ => Cmd::Wr { bank, col: (rng.below(128) * 8) as u32, auto_pre: false },
                };
                // structural feasibility first
                let open = dev.bank(bank).open_row.is_some();
                let feasible = match cmd {
                    Cmd::Act { .. } => !open,
                    Cmd::Pre { .. } | Cmd::Rd { .. } | Cmd::Wr { .. } => open,
                    _ => true,
                };
                if !feasible {
                    continue;
                }
                let at = dev.earliest_issue(cmd).max(now);
                if !dev.can_issue(cmd, at) {
                    return Err(format!("step {step}: {cmd} illegal at its earliest {at}"));
                }
                let early = dev.earliest_issue(cmd);
                if early > now && dev.can_issue(cmd, early - 1) {
                    return Err(format!("step {step}: {cmd} admitted before earliest"));
                }
                dev.issue(cmd, at);
                now = at;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_controller_conserves_requests() {
    check(
        "controller conservation",
        40,
        |rng| (rng.next_u64(), 1 + rng.below(60)),
        |&(seed, n)| {
            let geo = DramGeometry::profpga_board();
            let mut ctrl = MemController::new(
                ControllerParams::default(),
                TimingParams::for_bin(SpeedBin::Ddr4_1600),
                geo,
            );
            let mut rng = SplitMix64::new(seed);
            let mut pushed = 0u64;
            let mut done: Vec<ddr4bench::controller::Completion> = Vec::new();
            let mut now = 0u64;
            while pushed < n || done.len() < n as usize {
                if pushed < n {
                    let is_write = rng.percent(40);
                    let addr = rng.below(1 << 24) * 64;
                    let req = MemRequest {
                        txn_id: pushed,
                        is_write,
                        addr: geo.decode(addr),
                        burst_addr: addr,
                        beats: 2,
                        arrival: now,
                        last_of_txn: true,
                    };
                    if ctrl.try_push(req).is_ok() {
                        pushed += 1;
                    }
                }
                ctrl.tick(now);
                ctrl.pop_completions(now, &mut done);
                now += 1;
                if now > 1_000_000 {
                    return Err(format!("stalled: {} of {n} completed", done.len()));
                }
            }
            // conservation: every pushed id completes exactly once
            let mut ids: Vec<u64> = done.iter().map(|c| c.txn_id).collect();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() != n as usize {
                return Err(format!("{} unique completions for {n} requests", ids.len()));
            }
            // and completions are time-ordered
            for w in done.windows(2) {
                if w[0].done_at > w[1].done_at {
                    return Err("completions out of order".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_same_address_never_reorders() {
    check(
        "same-address ordering under mixed traffic",
        30,
        |rng| rng.next_u64(),
        |&seed| {
            let geo = DramGeometry::profpga_board();
            let mut ctrl = MemController::new(
                ControllerParams::default(),
                TimingParams::for_bin(SpeedBin::Ddr4_1600),
                geo,
            );
            let mut rng = SplitMix64::new(seed);
            // small address pool to force collisions
            let pool: Vec<u64> = (0..4).map(|i| i * 64).collect();
            let mut seq = Vec::new(); // (id, addr, is_write) in push order
            let mut done = Vec::new();
            let mut now = 0u64;
            let mut pushed = 0u64;
            let total = 24;
            while pushed < total || done.len() < total as usize {
                if pushed < total {
                    let addr = pool[rng.below(pool.len() as u64) as usize];
                    let is_write = rng.percent(50);
                    let req = MemRequest {
                        txn_id: pushed,
                        is_write,
                        addr: geo.decode(addr),
                        burst_addr: addr,
                        beats: 2,
                        arrival: now,
                        last_of_txn: true,
                    };
                    if ctrl.try_push(req).is_ok() {
                        seq.push((pushed, addr, is_write));
                        pushed += 1;
                    }
                }
                ctrl.tick(now);
                ctrl.pop_completions(now, &mut done);
                now += 1;
                if now > 2_000_000 {
                    return Err("stall".into());
                }
            }
            // For each address: the CAS (≈ done_at) order of its requests
            // must match push order.
            for addr in &pool {
                let pushed_ids: Vec<u64> =
                    seq.iter().filter(|(_, a, _)| a == addr).map(|(i, _, _)| *i).collect();
                let mut completed: Vec<(u64, u64)> = done
                    .iter()
                    .filter(|c| c.burst_addr == *addr)
                    .map(|c| (c.done_at, c.txn_id))
                    .collect();
                completed.sort_unstable();
                let completed_ids: Vec<u64> = completed.iter().map(|&(_, id)| id).collect();
                // write data lands CWL+4 after CAS vs CL+4 for reads, so
                // compare CAS-equivalent times: reconstruct via latency
                // classes is overkill — done_at order equals CAS order
                // within same-address groups because CAS spacing >= tCCD
                // exceeds the CL-CWL skew only when mixed... use a
                // relaxed check: no *later-pushed* request may complete
                // more than the read/write skew earlier.
                if completed_ids != pushed_ids {
                    // allow adjacent swaps only when the earlier is a
                    // write and later a read completing >= skew apart
                    return Err(format!(
                        "addr {addr:#x}: push order {pushed_ids:?} vs completion {completed_ids:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batch_counters_conserve() {
    check(
        "batch counter conservation",
        12,
        |rng| {
            let burst = 1 << rng.below(8); // 1..=128
            let batch = 16 + rng.below(200) as u32;
            let random = rng.percent(50);
            let op = match rng.below(3) {
                0 => OpMix::ReadOnly,
                1 => OpMix::WriteOnly,
                _ => OpMix::Mixed { read_pct: 25 + rng.below(51) as u32 },
            };
            let sig = match rng.below(3) {
                0 => Signaling::NonBlocking,
                1 => Signaling::Blocking,
                _ => Signaling::Aggressive,
            };
            (burst as u32, batch, random, op.read_pct(), matches!(sig, Signaling::Blocking))
        },
        |&(burst, batch, random, read_pct, blocking)| {
            let op = match read_pct {
                100 => OpMix::ReadOnly,
                0 => OpMix::WriteOnly,
                p => OpMix::Mixed { read_pct: p },
            };
            let mut cfg = PatternConfig::seq_read_burst(burst, batch);
            cfg.op = op;
            if random {
                cfg.addr = AddrMode::Random { seed: 77 };
            }
            if blocking {
                cfg.signaling = Signaling::Blocking;
            }
            let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
            let stats = platform.run_batch(0, &cfg).map_err(|e| e.to_string())?;
            let c = &stats.counters;
            if c.rd_txns + c.wr_txns != batch as u64 {
                return Err(format!("txns {} + {} != {batch}", c.rd_txns, c.wr_txns));
            }
            let txn_bytes = burst as u64 * 32;
            if c.rd_bytes != c.rd_txns * txn_bytes || c.wr_bytes != c.wr_txns * txn_bytes {
                return Err("byte counters inconsistent with txn counts".into());
            }
            if c.rd_latency.count() != c.rd_txns || c.wr_latency.count() != c.wr_txns {
                return Err("latency sample count != txn count".into());
            }
            if c.total_cycles < c.rd_cycles.max(c.wr_cycles) {
                return Err("total_cycles < per-direction cycles".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_telemetry_sampling_is_observation_only() {
    // The telemetry sampler must be a pure observer: with a window
    // armed, the batch counters (including TOTAL_CYCLES) and the
    // latency percentiles are bit-identical to the telemetry-off run —
    // across both engines and every scheduler policy.
    check(
        "telemetry on vs off: observables bit-identical",
        3,
        |rng| {
            let burst = [1u32, 8, 32][rng.below(3) as usize];
            let batch = 64 + rng.below(128) as u32;
            let mut cfg = match rng.below(3) {
                0 => PatternConfig::seq_read_burst(burst, batch),
                1 => PatternConfig::rnd_read_burst(burst, batch, rng.next_u64() >> 1),
                _ => PatternConfig::bank_conflict_read(1, batch, rng.next_u64() >> 1),
            };
            if rng.percent(40) {
                cfg.op = OpMix::Mixed { read_pct: 25 + rng.below(51) as u32 };
            }
            (cfg, 16 + rng.below(240))
        },
        |(cfg, window)| {
            for engine in EngineKind::ALL {
                for sched in SchedKind::ALL {
                    let mut design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
                    design.engine = engine;
                    design.controller.sched = sched;
                    let mut off = Platform::new(design.clone());
                    let mut on = Platform::new(design);
                    let a = off.run_batch(0, cfg).map_err(|e| e.to_string())?;
                    let mut armed = cfg.clone();
                    armed.telemetry = Some(*window);
                    let b = on.run_batch(0, &armed).map_err(|e| e.to_string())?;
                    if b.telemetry.is_none() {
                        return Err(format!("{engine}/{sched}: no series with TELEM={window}"));
                    }
                    if a.counters != b.counters {
                        return Err(format!(
                            "{engine}/{sched}: counters diverge with telemetry on\n  off: \
                             {:?}\n  on:  {:?}",
                            a.counters, b.counters
                        ));
                    }
                    for pct in [50.0, 99.0] {
                        let (ra, rb) = (a.read_latency_pct_ns(pct), b.read_latency_pct_ns(pct));
                        if ra.to_bits() != rb.to_bits() {
                            return Err(format!("{engine}/{sched}: read p{pct} {ra} vs {rb}"));
                        }
                        let (wa, wb) =
                            (a.write_latency_pct_ns(pct), b.write_latency_pct_ns(pct));
                        if wa.to_bits() != wb.to_bits() {
                            return Err(format!("{engine}/{sched}: write p{pct} {wa} vs {wb}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_every_sched_policy_preserves_order_and_serves_everyone() {
    // The two hard contracts of the scheduler subsystem, for every policy
    // x mapping combination: (a) same-address requests never reorder
    // (data integrity), and (b) every request is eventually served (no
    // starvation — the whole point of frfcfs-cap, but fcfs/closed/
    // adaptive must uphold it too).
    let mappings =
        [MappingPolicy::row_col_bank(), MappingPolicy::row_bank_col(), MappingPolicy::xor_hash()];
    for kind in SchedKind::ALL {
        for mapping in mappings {
            let mut geo = DramGeometry::profpga_board();
            geo.mapping = mapping;
            check(
                &format!("sched {kind} x {mapping}: ordering + eventual service"),
                6,
                |rng| rng.next_u64(),
                |&seed| {
                    let params = ControllerParams { sched: kind, ..Default::default() };
                    let mut ctrl = MemController::new(
                        params,
                        TimingParams::for_bin(SpeedBin::Ddr4_1600),
                        geo,
                    );
                    let mut rng = SplitMix64::new(seed);
                    // small pool to force same-address collisions
                    let pool: Vec<u64> = (0..4).map(|i| i * 64).collect();
                    let mut seq = Vec::new();
                    let mut done = Vec::new();
                    let mut now = 0u64;
                    let mut pushed = 0u64;
                    let total = 24;
                    while pushed < total || done.len() < total as usize {
                        if pushed < total {
                            let addr = pool[rng.below(pool.len() as u64) as usize];
                            let is_write = rng.percent(50);
                            let req = MemRequest {
                                txn_id: pushed,
                                is_write,
                                addr: geo.decode(addr),
                                burst_addr: addr,
                                beats: 2,
                                arrival: now,
                                last_of_txn: true,
                            };
                            if ctrl.try_push(req).is_ok() {
                                seq.push((pushed, addr));
                                pushed += 1;
                            }
                        }
                        ctrl.tick(now);
                        ctrl.pop_completions(now, &mut done);
                        now += 1;
                        if now > 2_000_000 {
                            return Err(format!(
                                "{kind}: starved — {} of {total} served",
                                done.len()
                            ));
                        }
                    }
                    // eventual service: each pushed id completes exactly once
                    let mut ids: Vec<u64> = done.iter().map(|c| c.txn_id).collect();
                    ids.sort_unstable();
                    ids.dedup();
                    if ids.len() != total as usize {
                        return Err(format!("{} unique completions of {total}", ids.len()));
                    }
                    // same-address ordering: completion order == push order
                    for addr in &pool {
                        let pushed_ids: Vec<u64> =
                            seq.iter().filter(|(_, a)| a == addr).map(|(i, _)| *i).collect();
                        let mut completed: Vec<(u64, u64)> = done
                            .iter()
                            .filter(|c| c.burst_addr == *addr)
                            .map(|c| (c.done_at, c.txn_id))
                            .collect();
                        completed.sort_unstable();
                        let completed_ids: Vec<u64> =
                            completed.iter().map(|&(_, id)| id).collect();
                        if completed_ids != pushed_ids {
                            return Err(format!(
                                "{kind}/{mapping}: addr {addr:#x} push {pushed_ids:?} vs \
                                 completion {completed_ids:?}"
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }
}

// --------------------------------------------------- pattern-engine modes

/// Draw one of the engine's address modes (all variants, random params).
fn gen_addr_mode(rng: &mut SplitMix64) -> AddrMode {
    match rng.below(6) {
        0 => AddrMode::Sequential,
        1 => AddrMode::Random { seed: rng.next_u64() >> 1 },
        2 => AddrMode::Strided { stride: 64 + rng.below(1 << 20) },
        3 => AddrMode::BankConflict { seed: rng.next_u64() >> 1 },
        4 => AddrMode::PointerChase {
            seed: rng.next_u64() >> 1,
            working_set: 4096 + rng.below(8 << 20),
        },
        _ => {
            let n = 1 + rng.below(3);
            let phases = (0..n)
                .map(|_| {
                    let inner = match rng.below(3) {
                        0 => AddrMode::Sequential,
                        1 => AddrMode::Random { seed: 11 },
                        _ => AddrMode::Strided { stride: 4096 },
                    };
                    (inner, 1 + rng.below(64) as u32)
                })
                .collect();
            AddrMode::Phased(phases)
        }
    }
}

#[test]
fn prop_every_mode_burst_aligned_and_in_region() {
    // The engine's core contract: whatever the mode, every generated
    // address is aligned to the transaction span and inside the region.
    let geo = DramGeometry::profpga_board();
    check(
        "all addr modes: aligned, in-region",
        300,
        |rng| {
            let mode = gen_addr_mode(rng);
            let burst = 1u32 << rng.below(8); // 1..=128
            let start = rng.below(1 << 28) & !63;
            let region = (1u64 << (17 + rng.below(10))).max(4096); // 128 KiB..64 MiB
            (mode, burst, start, region)
        },
        |(mode, burst, start, region)| {
            let mut cfg = PatternConfig::seq_read_burst(*burst, 1);
            cfg.addr = mode.clone();
            cfg.validate().map_err(|e| e.to_string())?;
            let spec = BurstSpec { len: *burst, kind: BurstKind::Incr };
            let mut g =
                ddr4bench::trafficgen::AddrGen::new(mode, *start, *region, spec, 32, &geo);
            let align = g.alignment();
            for i in 0..512 {
                let a = g.next_addr();
                if a % align != 0 {
                    return Err(format!("addr {i} = {a:#x} not {align}-aligned"));
                }
                if a < (*start & !(align - 1))
                    || a >= (*start & !(align - 1)) + (*region).max(align)
                {
                    return Err(format!("addr {i} = {a:#x} escapes region"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_same_seed_same_stream() {
    // Determinism across every mode: identical parameters => identical
    // address streams (the reproducibility contract of the paper's
    // run-time configuration).
    let geo = DramGeometry::profpga_board();
    check(
        "all addr modes: same seed => same stream",
        200,
        |rng| (gen_addr_mode(rng), 1u32 << rng.below(6)),
        |(mode, burst)| {
            let spec = BurstSpec { len: *burst, kind: BurstKind::Incr };
            let mut a =
                ddr4bench::trafficgen::AddrGen::new(mode, 0, 16 << 20, spec, 32, &geo);
            let mut b =
                ddr4bench::trafficgen::AddrGen::new(mode, 0, 16 << 20, spec, 32, &geo);
            for i in 0..256 {
                let (x, y) = (a.next_addr(), b.next_addr());
                if x != y {
                    return Err(format!("step {i}: {x:#x} != {y:#x}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pointer_chase_visits_whole_working_set() {
    // Full-period chase: over one cycle the chase touches every slot of
    // its (power-of-two) working set exactly once.
    let geo = DramGeometry::profpga_board();
    check(
        "pointer chase is a full-cycle permutation",
        80,
        |rng| {
            let slots_pow = 4 + rng.below(8); // 16..=2048 slots of 64 B
            (rng.next_u64() >> 1, 1u64 << slots_pow)
        },
        |&(seed, slots)| {
            let ws = slots * 64;
            let mode = AddrMode::PointerChase { seed, working_set: ws };
            let spec = BurstSpec { len: 1, kind: BurstKind::Incr };
            let mut g = ddr4bench::trafficgen::AddrGen::new(&mode, 0, 1 << 30, spec, 32, &geo);
            if g.chase_slots() != Some(slots) {
                return Err(format!("expected {slots} slots, got {:?}", g.chase_slots()));
            }
            let mut seen = std::collections::HashSet::new();
            for i in 0..slots {
                let a = g.next_addr();
                if a >= ws {
                    return Err(format!("addr {a:#x} outside working set {ws:#x}"));
                }
                if !seen.insert(a) {
                    return Err(format!("slot {a:#x} revisited at step {i} of {slots}"));
                }
            }
            if seen.len() as u64 != slots {
                return Err(format!("visited {} of {slots} slots", seen.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bank_conflict_pins_bank_and_walks_rows() {
    let geo = DramGeometry::profpga_board();
    check(
        "bank conflict: constant bank, fresh row each txn",
        100,
        |rng| rng.next_u64() >> 1,
        |&seed| {
            let mode = AddrMode::BankConflict { seed };
            let spec = BurstSpec { len: 1, kind: BurstKind::Incr };
            let mut g = ddr4bench::trafficgen::AddrGen::new(&mode, 0, 256 << 20, spec, 32, &geo);
            let mut prev: Option<ddr4bench::ddr4::DramAddr> = None;
            for _ in 0..128 {
                let d = geo.decode(g.next_addr());
                if let Some(p) = prev {
                    if d.bank != p.bank {
                        return Err(format!("bank drifted {} -> {}", p.bank, d.bank));
                    }
                    if d.row == p.row {
                        return Err(format!("row {} repeated back-to-back", d.row));
                    }
                }
                prev = Some(d);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_phased_is_exact_concatenation() {
    // A phased walk replays its component generators' streams verbatim,
    // switching after exactly the configured transaction counts.
    let geo = DramGeometry::profpga_board();
    check(
        "phased = interleaved component streams",
        100,
        |rng| {
            let a = 1 + rng.below(32) as u32;
            let b = 1 + rng.below(32) as u32;
            (rng.next_u64() >> 1, a, b)
        },
        |&(seed, na, nb)| {
            let spec = BurstSpec { len: 1, kind: BurstKind::Incr };
            let region = 1 << 20;
            let phased = AddrMode::Phased(vec![
                (AddrMode::Sequential, na),
                (AddrMode::Random { seed }, nb),
            ]);
            let mut g = ddr4bench::trafficgen::AddrGen::new(&phased, 0, region, spec, 32, &geo);
            let mut seq = ddr4bench::trafficgen::AddrGen::new(
                &AddrMode::Sequential,
                0,
                region,
                spec,
                32,
                &geo,
            );
            let mut rnd = ddr4bench::trafficgen::AddrGen::new(
                &AddrMode::Random { seed },
                0,
                region,
                spec,
                32,
                &geo,
            );
            for round in 0..3 {
                for i in 0..na {
                    let (x, y) = (g.next_addr(), seq.next_addr());
                    if x != y {
                        return Err(format!("round {round} seq[{i}]: {x:#x} != {y:#x}"));
                    }
                }
                for i in 0..nb {
                    let (x, y) = (g.next_addr(), rnd.next_addr());
                    if x != y {
                        return Err(format!("round {round} rnd[{i}]: {x:#x} != {y:#x}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pattern_config_roundtrip() {
    check(
        "CFG syntax round-trip",
        300,
        |rng| {
            let mut cfg = PatternConfig::seq_read_burst(
                1 + rng.below(128) as u32,
                1 + rng.below(10_000) as u32,
            );
            cfg.op = match rng.below(3) {
                0 => OpMix::ReadOnly,
                1 => OpMix::WriteOnly,
                _ => OpMix::Mixed { read_pct: rng.below(101) as u32 },
            };
            if rng.percent(50) {
                cfg.addr = AddrMode::Random { seed: rng.next_u64() >> 1 };
            }
            cfg.burst.kind = match rng.below(3) {
                0 => BurstKind::Fixed,
                1 => BurstKind::Incr,
                _ => BurstKind::Wrap,
            };
            if cfg.burst.kind == BurstKind::Wrap {
                cfg.burst.len = 1 << rng.below(5); // keep pow2 (1..16)
                cfg.burst.len = cfg.burst.len.max(2);
            }
            if cfg.burst.kind == BurstKind::Fixed {
                cfg.burst.len = cfg.burst.len.min(16);
            }
            cfg.signaling = match rng.below(3) {
                0 => Signaling::NonBlocking,
                1 => Signaling::Blocking,
                _ => Signaling::Aggressive,
            };
            cfg.start_addr = rng.below(1 << 30);
            cfg.region_bytes = 1 + rng.below(1 << 30);
            cfg.data = match rng.below(3) {
                0 => DataPattern::Prbs { seed: rng.next_u32() },
                1 => DataPattern::Zeros,
                _ => DataPattern::Constant(rng.next_u32()),
            };
            cfg.verify = rng.percent(50);
            cfg
        },
        |cfg| {
            if cfg.validate().is_err() {
                return Ok(()); // only valid configs must round-trip
            }
            let text = format_pattern_config(cfg);
            let toks: Vec<&str> = text.split_whitespace().collect();
            let parsed = parse_pattern_config(&toks).map_err(|e| e.to_string())?;
            if &parsed != cfg {
                return Err(format!("{cfg:?} -> `{text}` -> {parsed:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prbs_deterministic_and_nonzero() {
    check_shrink(
        "PRBS expansion deterministic + nonzero",
        2000,
        |rng| rng.next_u32(),
        |&seed| {
            let a = payload::expand_burst(seed);
            let b = payload::expand_burst(seed);
            if a != b {
                return Err("non-deterministic".into());
            }
            if a.iter().any(|&w| w == 0) {
                return Err(format!("zero word from seed {seed}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_verify_counts_exact_faults() {
    check(
        "verify counts exactly the planted faults",
        200,
        |rng| (rng.next_u64(), rng.below(50) as usize),
        |&(seed, nfaults)| {
            let mut rng = SplitMix64::new(seed);
            let seeds: Vec<u32> = (0..64).map(|_| rng.next_u32()).collect();
            let mut data = payload::expand_batch(&seeds);
            let mut positions = std::collections::HashSet::new();
            while positions.len() < nfaults {
                positions.insert(rng.below(data.len() as u64) as usize);
            }
            for &p in &positions {
                data[p] ^= 1 + (rng.next_u32() >> 1);
            }
            let got = payload::verify_batch(&seeds, &data);
            if got != nfaults as u64 {
                return Err(format!("planted {nfaults}, counted {got}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_channel_mix_isolated_from_neighbours() {
    use ddr4bench::config::ChannelMix;
    // Determinism + isolation invariant of the heterogeneous workload
    // engine: channels share no state, so every channel of a ChannelMix
    // must produce stats bit-identical to running its config solo on a
    // 1-channel design of the same speed.
    check(
        "heterogeneous mix channels match their solo runs",
        10,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = SplitMix64::new(seed);
            let k = rng.range_inclusive(1, 3) as usize;
            let mut cfgs = Vec::with_capacity(k);
            for _ in 0..k {
                let burst = [1u32, 4, 32][rng.below(3) as usize];
                let batch = 16 + rng.below(32) as u32;
                let mut cfg = match rng.below(5) {
                    0 => PatternConfig::seq_read_burst(burst, batch),
                    1 => PatternConfig::rnd_read_burst(burst, batch, rng.next_u64()),
                    2 => PatternConfig::strided_read(4096 + rng.below(64) * 64, burst, batch),
                    3 => PatternConfig::bank_conflict_read(burst, batch, rng.next_u64()),
                    _ => PatternConfig::pointer_chase_read(1 << 20, batch, rng.next_u64()),
                };
                if rng.percent(30) {
                    cfg.op = OpMix::Mixed { read_pct: rng.below(101) as u32 };
                }
                cfgs.push(cfg);
            }
            let mix = ChannelMix::new(cfgs.clone()).map_err(|e| e.to_string())?;
            let mut platform = Platform::new(DesignConfig::with_channels(k, SpeedBin::Ddr4_1600));
            let per = platform.run_batch_mix(&mix).map_err(|e| e.to_string())?;
            if per.len() != k {
                return Err(format!("{} stats for {k} channels", per.len()));
            }
            for (ch, cfg) in cfgs.iter().enumerate() {
                let mut solo = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
                let s = solo.run_batch(0, cfg).map_err(|e| e.to_string())?;
                if s.counters != per[ch].counters {
                    return Err(format!(
                        "channel {ch} ({cfg:?}) diverges from its solo run:\n  mix  \
                         {:?}\n  solo {:?}",
                        per[ch].counters, s.counters
                    ));
                }
            }
            Ok(())
        },
    );
}
