//! Host-controller protocol integration tests: full sessions over the
//! in-memory UART and over a real TCP socket, multi-channel independent
//! configuration (§II-C: "configuring independently each instantiated
//! traffic generator"), and statistics consistency between the protocol
//! and the underlying counters.

use std::io::{BufRead, BufReader, Write};

use ddr4bench::config::{DesignConfig, SpeedBin};
use ddr4bench::hostctrl::{serve_tcp, HostController};
use ddr4bench::platform::Platform;

fn host(channels: usize) -> HostController {
    HostController::new(Platform::new(DesignConfig::with_channels(
        channels,
        SpeedBin::Ddr4_1600,
    )))
}

fn get_field<'a>(resp: &'a str, key: &str) -> &'a str {
    resp.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&format!("{key}=")[..]))
        .unwrap_or_else(|| panic!("no {key} in `{resp}`"))
}

#[test]
fn independent_per_channel_configuration() {
    let mut h = host(3);
    // three different patterns on three channels
    assert!(h.handle_line("CFG 0 OP=R ADDR=SEQ BURST=32 BATCH=512").starts_with("OK"));
    assert!(h.handle_line("CFG 1 OP=W ADDR=RND SEED=1 BURST=1 BATCH=256").starts_with("OK"));
    assert!(h.handle_line("CFG 2 OP=M RDPCT=75 ADDR=SEQ BURST=128 BATCH=128").starts_with("OK"));
    let r = h.handle_line("RUNALL");
    assert!(r.starts_with("OK RUNALL CHANNELS=3"), "{r}");
    // per-channel stats reflect their own patterns
    let s0 = h.handle_line("STATS 0");
    let s1 = h.handle_line("STATS 1");
    let s2 = h.handle_line("STATS 2");
    assert_eq!(get_field(&s0, "RD_TXNS"), "512");
    assert_eq!(get_field(&s0, "WR_TXNS"), "0");
    assert_eq!(get_field(&s1, "WR_TXNS"), "256");
    assert_eq!(get_field(&s1, "RD_TXNS"), "0");
    let rd2: u64 = get_field(&s2, "RD_TXNS").parse().unwrap();
    let wr2: u64 = get_field(&s2, "WR_TXNS").parse().unwrap();
    assert_eq!(rd2 + wr2, 128);
    assert!(rd2 > wr2, "75% reads: {rd2} vs {wr2}");
}

#[test]
fn throughput_via_protocol_matches_direct_run() {
    // The host-reported RD_GBS must equal what a direct Platform run of
    // the same pattern measures (same executive underneath).
    let mut h = host(1);
    h.handle_line("CFG 0 OP=R ADDR=SEQ BURST=32 BATCH=2048");
    h.handle_line("RUN 0");
    let via_protocol: f64 = get_field(&h.handle_line("STATS 0"), "RD_GBS").parse().unwrap();

    let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    let direct = p
        .run_batch(0, &ddr4bench::config::PatternConfig::seq_read_burst(32, 2048))
        .unwrap()
        .read_throughput_gbs();
    assert!(
        (via_protocol - direct).abs() < 0.05,
        "protocol {via_protocol:.3} vs direct {direct:.3}"
    );
}

#[test]
fn verify_flow_reports_mismatches_over_protocol() {
    let mut h = host(1);
    h.handle_line("CFG 0 OP=W ADDR=SEQ BURST=4 BATCH=64 REGION=8k VERIFY=1");
    assert!(h.handle_line("RUN 0").starts_with("OK"));
    h.handle_line("CFG 0 OP=R ADDR=SEQ BURST=4 BATCH=64 REGION=8k VERIFY=1");
    assert!(h.handle_line("RUN 0").starts_with("OK"));
    let s = h.handle_line("STATS 0");
    assert_eq!(get_field(&s, "MISMATCHES"), "0");
}

#[test]
fn malformed_commands_answer_err_and_keep_session() {
    let mut h = host(1);
    for bad in [
        "",
        "CFG",
        "CFG 0 BURST=way_too_much",
        "CFG 0 BURST=200",
        "RUN x",
        "RUN 9",
        "STATS 0", // nothing ran yet
        "NONSENSE",
    ] {
        assert!(h.handle_line(bad).starts_with("ERR"), "`{bad}` should ERR");
    }
    // session still alive and functional
    h.handle_line("CFG 0 OP=R BATCH=64");
    assert!(h.handle_line("RUN 0").starts_with("OK"));
}

#[test]
fn uart_stream_session_transcript() {
    let mut h = host(1);
    let script = "INFO\nCFG 0 OP=R BURST=8 BATCH=128\nRUN 0\nSTATS 0\nQUIT\nRUN 0\n";
    let mut out = Vec::new();
    h.serve(std::io::Cursor::new(script.as_bytes().to_vec()), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    // session ends at QUIT: the trailing RUN never executes
    assert_eq!(lines.len(), 5, "{lines:?}");
    assert_eq!(lines[4], "OK BYE");
}

#[test]
fn tcp_server_serves_a_real_socket_session() {
    // The platform (and its PJRT handles) is not Send, so the server runs
    // on this thread — as on the FPGA, where the host controller is the
    // single master — and the *client* runs in a helper thread.
    let listener_host = host(1);
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap();
    drop(probe);
    let client = std::thread::spawn(move || {
        let mut stream = loop {
            match std::net::TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
            }
        };
        stream.write_all(b"INFO\nCFG 0 OP=W BURST=4 BATCH=128\nRUN 0\nSTATS 0\nQUIT\n").unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().map_while(Result::ok).collect();
        lines
    });
    let host_back = serve_tcp(listener_host, &addr.to_string(), Some(1)).unwrap();
    let lines = client.join().unwrap();
    assert_eq!(lines.len(), 5, "{lines:?}");
    assert!(lines[0].starts_with("OK CHANNELS=1"));
    assert!(lines[2].starts_with("OK RUN CH=0 TXNS=128"));
    assert!(lines[3].contains("WR_TXNS=128"));
    assert_eq!(lines[4], "OK BYE");
    assert_eq!(host_back.platform().channels(), 1);
}

#[test]
fn reset_isolates_channels() {
    let mut h = host(2);
    h.handle_line("CFG 0 OP=R BATCH=64");
    h.handle_line("CFG 1 OP=R BATCH=64");
    h.handle_line("RUN 0");
    h.handle_line("RUN 1");
    assert_eq!(h.handle_line("RESET 0"), "OK RESET");
    assert!(h.handle_line("STATS 0").starts_with("ERR"), "channel 0 cleared");
    assert!(h.handle_line("STATS 1").starts_with("OK"), "channel 1 untouched");
}
