//! Bench-server integration tests: N real TCP clients drive concurrent
//! sessions against one [`BenchServer`] and every transcript must be
//! byte-identical to a solo single-session `HostController` replay of
//! the same script — session isolation plus the shared worker pool must
//! be observationally invisible. Also: a client that vanishes
//! mid-session never poisons the pool, and per-session limits surface
//! their named `ERR LIMIT_*` diagnostics over the wire.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use ddr4bench::config::{DesignConfig, SessionLimits, SpeedBin};
use ddr4bench::hostctrl::{BenchServer, HostController, ServerConfig, ShutdownHandle};
use ddr4bench::platform::Platform;

fn design() -> DesignConfig {
    DesignConfig::with_channels(2, SpeedBin::Ddr4_1600)
}

/// Four deliberately different session scripts: plain read, seeded
/// random write, a heterogeneous CHCFG/RUNMIX flow, and a mixed-op
/// run with a RESET — so concurrent sessions exercise distinct state.
static SCRIPTS: [&[&str]; 4] = [
    &["INFO", "CFG 0 OP=R ADDR=SEQ BURST=32 BATCH=512", "RUN 0", "STATS 0", "QUIT"],
    &["CFG 0 OP=W ADDR=RND SEED=7 BURST=4 BATCH=256", "RUN 0", "STATS 0", "QUIT"],
    &[
        "CHCFG 0:SEQ,BURST=8,BATCH=128 1:BANK,SEED=3,BURST=1,BATCH=64",
        "RUNMIX",
        "STATS 0",
        "STATS 1",
        "QUIT",
    ],
    &["CFG 1 OP=M RDPCT=75 ADDR=SEQ BURST=16 BATCH=256", "RUN 1", "STATS 1", "RESET 1", "QUIT"],
];

/// The ground truth: the same script through a serial, inline,
/// unlimited session.
fn solo_replay(script: &[&str]) -> Vec<String> {
    let mut h = HostController::new(Platform::new(design()));
    script.iter().map(|line| h.handle_line(line)).collect()
}

fn run_client(addr: SocketAddr, script: &[&str]) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let reader = BufReader::new(stream);
    for line in script {
        writeln!(writer, "{line}").unwrap();
    }
    reader.lines().map_while(Result::ok).collect()
}

fn start(cfg: ServerConfig) -> (SocketAddr, ShutdownHandle, std::thread::JoinHandle<()>) {
    let server = BenchServer::bind(design(), cfg, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let shutdown = server.shutdown_handle().unwrap();
    let serving = std::thread::spawn(move || server.run().unwrap());
    (addr, shutdown, serving)
}

#[test]
fn concurrent_sessions_match_solo_replay_bit_for_bit() {
    let cfg = ServerConfig { workers: 2, max_sessions: 8, ..ServerConfig::default() };
    let (addr, shutdown, serving) = start(cfg);

    // all four clients in flight at once, each with a distinct script
    let clients: Vec<_> = SCRIPTS
        .iter()
        .map(|script| std::thread::spawn(move || run_client(addr, script)))
        .collect();
    for (i, client) in clients.into_iter().enumerate() {
        let got = client.join().unwrap();
        let want = solo_replay(SCRIPTS[i]);
        assert_eq!(got, want, "client {i} transcript diverges from solo replay");
    }

    shutdown.signal();
    serving.join().unwrap();
}

#[test]
fn dropped_client_never_poisons_the_server_or_pool() {
    let cfg = ServerConfig { workers: 1, max_sessions: 4, ..ServerConfig::default() };
    let (addr, shutdown, serving) = start(cfg);

    // a client queues real work and vanishes without reading a byte
    {
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        writeln!(w, "CFG 0 OP=R ADDR=SEQ BURST=32 BATCH=4096").unwrap();
        writeln!(w, "RUN 0").unwrap();
    }

    // the same (single-worker) pool still answers a fresh client with
    // bit-identical results
    let got = run_client(addr, SCRIPTS[0]);
    assert_eq!(got, solo_replay(SCRIPTS[0]), "transcript diverges after a dropped client");

    shutdown.signal();
    serving.join().unwrap();
}

#[test]
fn metrics_reaches_a_streaming_client_mid_run_over_tcp() {
    // A client that pipelines RUN + METRICS with streaming on and a
    // telemetry window armed must see live heartbeats while the run is
    // in flight, then a well-formed snapshot once it lands.
    let cfg = ServerConfig {
        workers: 1,
        max_sessions: 2,
        stream_interval: Duration::from_millis(1),
        ..ServerConfig::default()
    };
    let (addr, shutdown, serving) = start(cfg);

    let all = run_client(
        addr,
        &[
            "STREAM ON",
            "CFG 0 OP=R ADDR=RND SEED=9 BURST=1 BATCH=60000 TELEM=256",
            "RUN 0",
            "METRICS 0",
            "QUIT",
        ],
    );

    let beats: Vec<&String> = all.iter().filter(|l| l.starts_with("STREAM ")).collect();
    let replies: Vec<&String> = all.iter().filter(|l| !l.starts_with("STREAM ")).collect();
    assert!(!beats.is_empty(), "no heartbeat arrived during the run: {all:?}");
    assert!(beats.iter().all(|b| b.starts_with("STREAM RUN CH=0 MS=")), "{beats:?}");
    assert!(
        beats.iter().any(|b| b.contains(" bw=") && b.contains(" qd=") && b.contains(" p99=")),
        "no heartbeat carried live telemetry: {beats:?}"
    );
    // every heartbeat belongs to the run: all precede the RUN reply
    let run_pos = all.iter().position(|l| l.starts_with("OK RUN CH=0")).expect("RUN reply");
    let last_beat = all.iter().rposition(|l| l.starts_with("STREAM ")).unwrap();
    assert!(last_beat < run_pos, "heartbeat after the RUN reply: {all:?}");

    assert_eq!(replies.len(), 5, "{all:?}");
    assert_eq!(replies[0], "OK STREAM ON");
    assert!(replies[1].starts_with("OK CFG CH=0"), "{}", replies[1]);
    assert!(replies[2].starts_with("OK RUN CH=0 TXNS=60000"), "{}", replies[2]);
    let metrics = replies[3];
    assert!(metrics.starts_with("OK METRICS CH=0 WINDOW=256 CLOSED="), "{metrics}");
    assert!(metrics.contains(" DONE=1"), "{metrics}");
    assert!(metrics.contains(" LAST_START="), "{metrics}");
    assert!(metrics.contains(" RD_P99="), "{metrics}");
    let closed: u64 = metrics
        .split(" CLOSED=")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .and_then(|n| n.parse().ok())
        .expect("CLOSED= field");
    assert!(closed > 0, "snapshot closed no windows: {metrics}");
    assert_eq!(replies[4], "OK BYE");

    shutdown.signal();
    serving.join().unwrap();
}

#[test]
fn per_session_limits_surface_named_diagnostics_over_tcp() {
    let limits = SessionLimits { max_channels: 1, max_batch: 128, max_queued_runs: 1 };
    let cfg = ServerConfig { workers: 1, max_sessions: 2, limits, ..ServerConfig::default() };
    let (addr, shutdown, serving) = start(cfg);

    let got = run_client(
        addr,
        &[
            "CFG 0 OP=R BATCH=512",
            "CFG 1 OP=R BATCH=64",
            "RUNALL",
            "CFG 0 OP=R ADDR=SEQ BURST=4 BATCH=64",
            "RUN 0",
            "QUIT",
        ],
    );
    assert_eq!(got.len(), 6, "{got:?}");
    assert!(got[0].starts_with("ERR LIMIT_BATCH:"), "{}", got[0]);
    assert!(got[1].starts_with("ERR LIMIT_CHANNELS:"), "{}", got[1]);
    assert!(got[2].starts_with("ERR LIMIT_CHANNELS:"), "{}", got[2]);
    assert!(got[3].starts_with("OK CFG CH=0"), "{}", got[3]);
    assert!(got[4].starts_with("OK RUN CH=0 TXNS=64"), "{}", got[4]);
    assert_eq!(got[5], "OK BYE");

    shutdown.signal();
    serving.join().unwrap();
}
