//! Differential test of the `controller::sched` refactor: the default
//! `frfcfs` policy must reproduce the pre-refactor monolithic scheduler
//! **command for command** on randomized request streams.
//!
//! `RefController` below is a frozen copy of the monolithic
//! `MemController` exactly as it stood before the scheduler was
//! decomposed behind the `SchedPolicy` trait (PR 4). Both controllers
//! are driven with identical pushes at identical cycles; every tick's
//! issued command and every completion must match bit-exactly.

use std::collections::VecDeque;

use ddr4bench::config::{ControllerParams, SpeedBin};
use ddr4bench::controller::{Completion, MemController, MemRequest};
use ddr4bench::ddr4::{Cmd, Cycle, DdrDevice, DramGeometry, TimingParams};
use ddr4bench::rng::SplitMix64;
use ddr4bench::testkit::check;

// ------------------------------------------------------------------------
// Frozen pre-refactor controller (verbatim scheduler logic; accessors and
// statistics that the differential driver does not need are omitted).
// ------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Read,
    Write,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RefreshState {
    Idle,
    Draining,
}

#[allow(dead_code)] // counters kept for fidelity with the original
#[derive(Debug, Clone, Copy, Default)]
struct RefCtrlStats {
    refresh_stall_cycles: u64,
    mode_switches: u64,
    queue_rejects: u64,
}

struct RefController {
    params: ControllerParams,
    device: DdrDevice,
    read_q: VecDeque<MemRequest>,
    write_q: VecDeque<MemRequest>,
    completions: VecDeque<Completion>,
    mode: Mode,
    refresh: RefreshState,
    read_gate_until: Cycle,
    write_gate_until: Cycle,
    mode_entered: Cycle,
    bank_last_use: Vec<Cycle>,
    dirty: bool,
    idle_until: Cycle,
    stats: RefCtrlStats,
}

impl RefController {
    fn new(params: ControllerParams, timing: TimingParams, geometry: DramGeometry) -> Self {
        let banks = geometry.banks() as usize;
        Self {
            bank_last_use: vec![0; banks],
            dirty: true,
            idle_until: 0,
            params,
            device: DdrDevice::new(timing, geometry),
            read_q: VecDeque::with_capacity(params.read_queue_depth),
            write_q: VecDeque::with_capacity(params.write_queue_depth),
            completions: VecDeque::new(),
            mode: Mode::Read,
            refresh: RefreshState::Idle,
            read_gate_until: 0,
            write_gate_until: 0,
            mode_entered: 0,
            stats: RefCtrlStats::default(),
        }
    }

    fn try_push(&mut self, req: MemRequest) -> Result<(), MemRequest> {
        let cap =
            if req.is_write { self.params.write_queue_depth } else { self.params.read_queue_depth };
        let len = if req.is_write { self.write_q.len() } else { self.read_q.len() };
        if len >= cap {
            self.stats.queue_rejects += 1;
            return Err(req);
        }
        let q = if req.is_write { &mut self.write_q } else { &mut self.read_q };
        q.push_back(req);
        self.dirty = true;
        Ok(())
    }

    fn pop_completions(&mut self, now: Cycle, out: &mut Vec<Completion>) {
        while let Some(c) = self.completions.front() {
            if c.done_at <= now {
                out.push(*c);
                self.completions.pop_front();
            } else {
                break;
            }
        }
    }

    fn tick(&mut self, now: Cycle) -> Option<Cmd> {
        if !self.dirty && now < self.idle_until && self.refresh == RefreshState::Idle {
            return None;
        }
        self.dirty = false;
        let cmd = self.tick_eval(now);
        if cmd.is_some() {
            self.idle_until = 0;
        }
        cmd
    }

    fn tick_eval(&mut self, now: Cycle) -> Option<Cmd> {
        if self.refresh != RefreshState::Idle || self.device.refresh_needed(now) {
            if let Some(cmd) = self.tick_refresh(now) {
                return Some(cmd);
            }
            if self.refresh != RefreshState::Idle {
                self.stats.refresh_stall_cycles += 1;
                return None;
            }
        }

        self.update_mode(now);
        let mut wake = self.device.refresh_due();
        if !self.read_q.is_empty() || !self.write_q.is_empty() {
            wake = wake.min(self.mode_entered + (self.params.mode_dwell_ck / 4).max(1) as Cycle);
        }

        match self.try_cas(now) {
            (Some(cmd), _) => return Some(cmd),
            (None, w) => wake = wake.min(w),
        }

        match self.try_prep(now, self.mode) {
            (Some(cmd), _) => return Some(cmd),
            (None, w) => wake = wake.min(w),
        }
        let other = match self.mode {
            Mode::Read => Mode::Write,
            Mode::Write => Mode::Read,
        };
        match self.try_prep(now, other) {
            (Some(cmd), _) => return Some(cmd),
            (None, w) => wake = wake.min(w),
        }
        match self.try_idle_precharge(now) {
            (Some(cmd), _) => return Some(cmd),
            (None, w) => wake = wake.min(w),
        }
        self.idle_until = wake.max(now + 1);
        None
    }

    fn try_idle_precharge(&mut self, now: Cycle) -> (Option<Cmd>, Cycle) {
        let timer = self.params.idle_precharge_cycles;
        if timer == 0 {
            return (None, Cycle::MAX);
        }
        let mut wake = Cycle::MAX;
        for bank in 0..self.bank_last_use.len() {
            let b = self.device.bank(bank as u32);
            let Some(open_row) = b.open_row else { continue };
            let expires = self.bank_last_use[bank] + timer as Cycle;
            if now < expires {
                wake = wake.min(expires);
                continue;
            }
            let wanted = self
                .read_q
                .iter()
                .chain(self.write_q.iter())
                .any(|r| r.addr.bank == bank as u32 && r.addr.row == open_row);
            if wanted {
                continue;
            }
            let cmd = Cmd::Pre { bank: bank as u32 };
            let at = self.device.earliest_issue(cmd);
            if at <= now && self.device.can_issue(cmd, now) {
                self.device.issue(cmd, now);
                return (Some(cmd), now);
            }
            wake = wake.min(at);
        }
        (None, wake)
    }

    fn tick_refresh(&mut self, now: Cycle) -> Option<Cmd> {
        match self.refresh {
            RefreshState::Idle => {
                if self.device.all_banks_closed() {
                    if self.device.can_issue(Cmd::Ref, now) {
                        self.device.issue(Cmd::Ref, now);
                        self.stats.refresh_stall_cycles += self.device.timing().trfc as u64;
                        return Some(Cmd::Ref);
                    }
                    self.refresh = RefreshState::Draining;
                    None
                } else if self.device.can_issue(Cmd::PreAll, now) {
                    self.device.issue(Cmd::PreAll, now);
                    self.refresh = RefreshState::Draining;
                    Some(Cmd::PreAll)
                } else {
                    self.refresh = RefreshState::Draining;
                    None
                }
            }
            RefreshState::Draining => {
                if !self.device.all_banks_closed() {
                    if self.device.can_issue(Cmd::PreAll, now) {
                        self.device.issue(Cmd::PreAll, now);
                        return Some(Cmd::PreAll);
                    }
                    return None;
                }
                if self.device.can_issue(Cmd::Ref, now) {
                    self.device.issue(Cmd::Ref, now);
                    self.refresh = RefreshState::Idle;
                    self.stats.refresh_stall_cycles += self.device.timing().trfc as u64;
                    return Some(Cmd::Ref);
                }
                None
            }
        }
    }

    fn update_mode(&mut self, now: Cycle) {
        let wlen = self.write_q.len();
        let dwell = self.params.mode_dwell_ck as Cycle;
        let dwell_ok = now >= self.mode_entered + dwell;
        let grace_ok = now >= self.mode_entered + dwell / 4;
        let switch = match self.mode {
            Mode::Read => {
                wlen >= self.params.write_drain_high
                    || self.head_hazard_blocked(false)
                    || (wlen > 0 && dwell_ok && !self.read_q.is_empty())
                    || (wlen > 0 && grace_ok && self.read_q.is_empty())
            }
            Mode::Write => {
                self.head_hazard_blocked(true)
                    || (!self.read_q.is_empty()
                        && (wlen <= self.params.write_drain_low || dwell_ok))
                    || (wlen == 0 && grace_ok && !self.read_q.is_empty())
            }
        };
        if switch {
            self.mode = match self.mode {
                Mode::Read => Mode::Write,
                Mode::Write => Mode::Read,
            };
            self.mode_entered = now;
            self.stats.mode_switches += 1;
        }
    }

    fn head_hazard_blocked(&self, is_write: bool) -> bool {
        let (q, other) =
            if is_write { (&self.write_q, &self.read_q) } else { (&self.read_q, &self.write_q) };
        let Some(head) = q.front() else { return false };
        other.iter().any(|r| r.addr == head.addr && r.arrival < head.arrival)
    }

    fn try_cas(&mut self, now: Cycle) -> (Option<Cmd>, Cycle) {
        let is_write = self.mode == Mode::Write;
        let look = self.params.lookahead;
        let (q, t) = match self.mode {
            Mode::Read => (&self.read_q, self.device.timing()),
            Mode::Write => (&self.write_q, self.device.timing()),
        };
        let (cl, cwl, burst) = (t.cl, t.cwl, t.burst_cycles);

        let mut pick: Option<usize> = None;
        let mut wake = Cycle::MAX;
        for (i, req) in q.iter().take(look).enumerate() {
            if self.device.row_state(req.addr.bank, req.addr.row) == Some(true) {
                let cmd = if is_write {
                    Cmd::Wr { bank: req.addr.bank, col: req.addr.col, auto_pre: false }
                } else {
                    Cmd::Rd { bank: req.addr.bank, col: req.addr.col, auto_pre: false }
                };
                if self.reordered_past_same_addr(i, is_write) {
                    continue;
                }
                let at = self.device.earliest_issue(cmd);
                if at <= now {
                    pick = Some(i);
                    break;
                }
                wake = wake.min(at);
            }
        }
        let Some(i) = pick else { return (None, wake) };
        let req = if is_write {
            self.write_q.remove(i).unwrap()
        } else {
            self.read_q.remove(i).unwrap()
        };
        let cmd = if is_write {
            Cmd::Wr { bank: req.addr.bank, col: req.addr.col, auto_pre: false }
        } else {
            Cmd::Rd { bank: req.addr.bank, col: req.addr.col, auto_pre: false }
        };
        self.device.issue(cmd, now);
        self.bank_last_use[req.addr.bank as usize] = now;
        let done_at = now + if is_write { cwl + burst } else { cl + burst } as Cycle;
        let comp = Completion {
            txn_id: req.txn_id,
            is_write,
            burst_addr: req.burst_addr,
            beats: req.beats,
            done_at,
            arrival: req.arrival,
            last_of_txn: req.last_of_txn,
        };
        let pos = self
            .completions
            .iter()
            .rposition(|c| c.done_at <= done_at)
            .map(|p| p + 1)
            .unwrap_or(0);
        self.completions.insert(pos, comp);
        (Some(cmd), now)
    }

    fn reordered_past_same_addr(&self, i: usize, is_write: bool) -> bool {
        let q = if is_write { &self.write_q } else { &self.read_q };
        let target = q[i].addr;
        if q.iter().take(i).any(|r| r.addr == target) {
            return true;
        }
        let other = if is_write { &self.read_q } else { &self.write_q };
        let my_arrival = q[i].arrival;
        other.iter().any(|r| r.addr == target && r.arrival < my_arrival)
    }

    fn try_prep(&mut self, now: Cycle, mode: Mode) -> (Option<Cmd>, Cycle) {
        let look = self.params.lookahead;
        let q = match mode {
            Mode::Read => &self.read_q,
            Mode::Write => &self.write_q,
        };
        let mut seen_banks = 0u32;
        let mut act_target: Option<(u32, u32)> = None;
        let mut pre_target: Option<u32> = None;
        for req in q.iter().take(look) {
            let bit = 1u32 << req.addr.bank;
            if seen_banks & bit != 0 {
                continue;
            }
            seen_banks |= bit;
            match self.device.row_state(req.addr.bank, req.addr.row) {
                None => {
                    if act_target.is_none() {
                        act_target = Some((req.addr.bank, req.addr.row));
                    }
                }
                Some(false) => {
                    let open = self.device.bank(req.addr.bank).open_row;
                    let still_wanted = q.iter().take(look).any(|r| {
                        r.addr.bank == req.addr.bank
                            && Some(r.addr.row) == open
                            && r.arrival < req.arrival
                    });
                    if !still_wanted && pre_target.is_none() {
                        pre_target = Some(req.addr.bank);
                    }
                }
                Some(true) => {}
            }
        }
        let mut wake = Cycle::MAX;
        if let Some((bank, row)) = act_target {
            let cmd = Cmd::Act { bank, row };
            let at = self.device.earliest_issue(cmd);
            if at <= now {
                self.device.issue(cmd, now);
                if self.params.miss_flush {
                    let t = self.device.timing();
                    let gate = match mode {
                        Mode::Read => {
                            now + (t.trcd + t.cl + t.burst_cycles + t.trp) as Cycle
                        }
                        Mode::Write => {
                            now + (t.trcd + t.cwl + t.burst_cycles + t.twr + t.twtr_l)
                                as Cycle
                        }
                    };
                    match mode {
                        Mode::Read => self.read_gate_until = self.read_gate_until.max(gate),
                        Mode::Write => self.write_gate_until = self.write_gate_until.max(gate),
                    }
                }
                return (Some(cmd), now);
            }
            wake = wake.min(at);
        }
        if let Some(bank) = pre_target {
            let cmd = Cmd::Pre { bank };
            let at = self.device.earliest_issue(cmd);
            if at <= now && self.device.can_issue(cmd, now) {
                self.device.issue(cmd, now);
                return (Some(cmd), now);
            }
            wake = wake.min(at);
        }
        (None, wake)
    }
}

// ------------------------------------------------------------------------
// The differential driver
// ------------------------------------------------------------------------

/// Address streams the differential drivers can generate. `Mixed` is
/// the original pool+uniform stream; the other two are the adversarial
/// shapes where queue depth and window size matter most — all of one
/// bank's rows fighting over its row buffer, and a dependent-looking
/// walk over a small working set (heavy same-address revisits, the
/// stress case for the indexed scheduler's duplicate-address paths).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AddrStream {
    /// Small same-address pool mixed with uniform addresses.
    Mixed,
    /// Every request in one bank, hopping across its rows.
    BankConflict,
    /// Multiplicative walk over a small region (pointer-chase-like).
    Chase,
}

/// Stateful address generator for one [`AddrStream`].
struct StreamGen {
    stream: AddrStream,
    pool: Vec<u64>,
    row_step: u64,
    cursor: u64,
}

impl StreamGen {
    fn new(stream: AddrStream, geo: &DramGeometry, seed: u64) -> Self {
        Self {
            stream,
            pool: (0..8).map(|i| i * 64).collect(),
            row_step: geo.row_step_bytes(),
            cursor: seed | 1,
        }
    }

    fn next(&mut self, rng: &mut SplitMix64) -> u64 {
        match self.stream {
            AddrStream::Mixed => {
                if rng.percent(20) {
                    self.pool[rng.below(self.pool.len() as u64) as usize]
                } else {
                    rng.below(1 << 22) * 64
                }
            }
            // same bank (the lowest mapping field stays 0), 512 rows
            AddrStream::BankConflict => rng.below(1 << 9) * self.row_step,
            AddrStream::Chase => {
                self.cursor = self.cursor.wrapping_mul(6364136223846793005).wrapping_add(1);
                (self.cursor >> 16) % (1 << 12) * 64
            }
        }
    }
}

/// Drive both controllers with an identical randomized request stream and
/// compare every tick's command and every completion.
fn run_differential(seed: u64, params: ControllerParams, cycles: u64) -> Result<(), String> {
    run_differential_stream(seed, params, cycles, AddrStream::Mixed, 35)
}

/// [`run_differential`] with a selectable address stream and push rate
/// (percent chance of an enqueue attempt per cycle — high rates keep
/// deep queues saturated so wide windows actually fill).
fn run_differential_stream(
    seed: u64,
    params: ControllerParams,
    cycles: u64,
    stream: AddrStream,
    push_pct: u32,
) -> Result<(), String> {
    let geo = DramGeometry::profpga_board();
    let timing = TimingParams::for_bin(SpeedBin::Ddr4_1600);
    let mut new_ctrl = MemController::new(params, timing, geo);
    let mut ref_ctrl = RefController::new(params, timing, geo);
    let mut rng = SplitMix64::new(seed);
    // a small pool mixed with uniform addresses forces same-address
    // hazards through both schedulers
    let mut gen = StreamGen::new(stream, &geo, seed);
    let mut id = 0u64;
    let mut done_new: Vec<Completion> = Vec::new();
    let mut done_ref: Vec<Completion> = Vec::new();
    for now in 0..cycles {
        if rng.percent(push_pct) {
            let is_write = rng.percent(40);
            let addr = gen.next(&mut rng);
            let req = MemRequest {
                txn_id: id,
                is_write,
                addr: geo.decode(addr),
                burst_addr: addr,
                beats: 2,
                arrival: now,
                last_of_txn: true,
            };
            let a = new_ctrl.try_push(req);
            let b = ref_ctrl.try_push(req);
            if a.is_ok() != b.is_ok() {
                return Err(format!(
                    "cycle {now}: push divergence (new {:?} vs ref {:?})",
                    a.is_ok(),
                    b.is_ok()
                ));
            }
            if a.is_ok() {
                id += 1;
            }
        }
        let ca = new_ctrl.tick(now);
        let cb = ref_ctrl.tick(now);
        if ca != cb {
            return Err(format!("cycle {now}: command divergence {ca:?} vs {cb:?}"));
        }
        new_ctrl.pop_completions(now, &mut done_new);
        ref_ctrl.pop_completions(now, &mut done_ref);
        if done_new.len() != done_ref.len() {
            return Err(format!(
                "cycle {now}: completion count divergence {} vs {}",
                done_new.len(),
                done_ref.len()
            ));
        }
    }
    if done_new != done_ref {
        return Err("completion streams diverge".into());
    }
    if done_new.is_empty() {
        return Err("differential run serviced no requests".into());
    }
    Ok(())
}

#[test]
fn frfcfs_matches_prerefactor_scheduler_command_for_command() {
    check(
        "frfcfs differential vs frozen monolith",
        6,
        |rng| rng.next_u64(),
        |&seed| run_differential(seed, ControllerParams::default(), 60_000),
    )
}

#[test]
fn frfcfs_differential_holds_across_knob_profiles() {
    // the bit-exactness contract covers the knob space, not just the
    // MIG-like defaults: vary the window, the page timer and the dwell
    check(
        "frfcfs differential across knob profiles",
        6,
        |rng| {
            let lookahead = [1usize, 4, 8][rng.below(3) as usize];
            let idle = [0u32, 64][rng.below(2) as usize];
            let dwell = [8u32, 48][rng.below(2) as usize];
            (rng.next_u64(), lookahead, idle, dwell)
        },
        |&(seed, lookahead, idle, dwell)| {
            let params = ControllerParams {
                lookahead,
                idle_precharge_cycles: idle,
                mode_dwell_ck: dwell,
                ..Default::default()
            };
            run_differential(seed, params, 40_000)
        },
    )
}

#[test]
fn frfcfs_differential_deep_queues_saturated() {
    // The windows where the indexed scheduler earns its keep: depth-64
    // queues kept brimming (90% push rate) under wide lookahead, on the
    // adversarial streams — every request to one bank, and a
    // pointer-chase-like walk thick with same-address revisits. The
    // oracle must still be matched tick for tick.
    check(
        "frfcfs differential, deep saturated queues",
        6,
        |rng| {
            let lookahead = [8usize, 32][rng.below(2) as usize];
            let stream = [AddrStream::Mixed, AddrStream::BankConflict, AddrStream::Chase]
                [rng.below(3) as usize];
            (rng.next_u64(), lookahead, stream)
        },
        |&(seed, lookahead, stream)| {
            let params = ControllerParams {
                lookahead,
                read_queue_depth: 64,
                write_queue_depth: 64,
                write_drain_high: 48,
                write_drain_low: 8,
                ..Default::default()
            };
            run_differential_stream(seed, params, 40_000, stream, 90)
        },
    )
}
