//! Mutation proof of the protocol-legality analyzer (`check::`).
//!
//! Strategy (ISSUE 10): the auditor is only trustworthy if (a) every
//! rule in the rulebook actually fires — proven here by corrupting one
//! command of a legal stream per rule and asserting the *specific* rule
//! ID reports — and (b) it never cries wolf — proven by arming the live
//! audit over the full scheduler x engine x mapping grid plus randomized
//! patterns and asserting zero violations, then checking that a
//! truncated trace (ring overflow) is reported as TRUNCATED rather than
//! certified clean.
//!
//! All hand-built streams use the DDR4-1600 rulebook (tRCD=tRP=11,
//! tRAS=28, tRC=39, tCCD_S/L=4/5, tRRD_S/L=5/6, tFAW=28, tWR recovery
//! 25, tRTP=6, tWTR_S/L recovery 15/19, RD->WR 8, tRFC=208,
//! 9*tREFI=56160) and the flat-bank convention of the trace: banks 0/1
//! sit in group 0, bank 4 in group 1.

use ddr4bench::check::mutate::{apply, Mutation};
use ddr4bench::check::{offline, report, Auditor, RuleId, Rulebook, Status, StreamStart};
use ddr4bench::config::{parse_pattern_config, DesignConfig, SpeedBin};
use ddr4bench::ddr4::TimingParams;
use ddr4bench::obs::cmdtrace::{TraceCmd, TraceEvent};
use ddr4bench::platform::Platform;
use ddr4bench::testkit::check;

fn timing() -> TimingParams {
    TimingParams::for_bin(SpeedBin::Ddr4_1600)
}

fn ev(cycle: u64, cmd: TraceCmd, bank_group: u32, bank: u32, row: u32) -> TraceEvent {
    TraceEvent { cycle, cmd, bank_group, bank, row }
}

fn audit(events: &[TraceEvent]) -> Auditor {
    let mut a = Auditor::new(&timing(), StreamStart::Complete);
    for e in events {
        a.observe(e);
    }
    a
}

/// One mutation case: a legal baseline stream, one corruption, and the
/// rule that must catch it.
struct Case {
    name: &'static str,
    rule: RuleId,
    baseline: Vec<TraceEvent>,
    mutation: Mutation,
}

use TraceCmd::{Act, Pre, Rd, Ref, Wr};

/// The full matrix: one case per rule in the book.
fn cases() -> Vec<Case> {
    vec![
        Case {
            name: "early CAS after ACT",
            rule: RuleId::Trcd,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1020, Rd, 0, 0, 5)],
            // gap 10 < tRCD 11
            mutation: Mutation::ShiftTo { index: 1, cycle: 1010 },
        },
        Case {
            name: "re-ACT too soon after PRE",
            rule: RuleId::Trp,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1048, Pre, 0, 0, 5), ev(1060, Act, 0, 0, 6)],
            // gap 10 < tRP 11 (tRC from ACT@1000 is long satisfied)
            mutation: Mutation::ShiftTo { index: 2, cycle: 1058 },
        },
        Case {
            name: "PRE before the row aged tRAS",
            rule: RuleId::Tras,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1028, Pre, 0, 0, 5)],
            // gap 27 < tRAS 28
            mutation: Mutation::ShiftTo { index: 1, cycle: 1027 },
        },
        Case {
            name: "ACT-to-ACT same bank under tRC",
            rule: RuleId::Trc,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1028, Pre, 0, 0, 5), ev(1039, Act, 0, 0, 6)],
            // gap 38 < tRC 39 (also trips tRP; the case asserts tRC fired)
            mutation: Mutation::ShiftTo { index: 2, cycle: 1038 },
        },
        Case {
            name: "CAS-to-CAS cross group under tCCD_S",
            rule: RuleId::TccdS,
            baseline: vec![
                ev(1000, Act, 0, 0, 5),
                ev(1005, Act, 1, 4, 5),
                ev(1020, Rd, 0, 0, 5),
                ev(1024, Rd, 1, 4, 5),
            ],
            // gap 3 < tCCD_S 4
            mutation: Mutation::ShiftTo { index: 3, cycle: 1023 },
        },
        Case {
            name: "CAS-to-CAS same group under tCCD_L",
            rule: RuleId::TccdL,
            baseline: vec![
                ev(1000, Act, 0, 0, 5),
                ev(1006, Act, 0, 1, 5),
                ev(1020, Rd, 0, 0, 5),
                ev(1025, Rd, 0, 1, 5),
            ],
            // gap 4: legal for tCCD_S, short of tCCD_L 5
            mutation: Mutation::ShiftTo { index: 3, cycle: 1024 },
        },
        Case {
            name: "ACT-to-ACT cross group under tRRD_S",
            rule: RuleId::TrrdS,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1005, Act, 1, 4, 5)],
            // gap 4 < tRRD_S 5
            mutation: Mutation::ShiftTo { index: 1, cycle: 1004 },
        },
        Case {
            name: "ACT-to-ACT same group under tRRD_L",
            rule: RuleId::TrrdL,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1006, Act, 0, 1, 5)],
            // gap 5: legal for tRRD_S, short of tRRD_L 6
            mutation: Mutation::ShiftTo { index: 1, cycle: 1005 },
        },
        Case {
            name: "fifth ACT inside the tFAW window",
            rule: RuleId::Tfaw,
            baseline: vec![
                ev(1000, Act, 0, 0, 1),
                ev(1005, Act, 1, 4, 1),
                ev(1010, Act, 0, 1, 1),
                ev(1016, Act, 1, 5, 1),
                ev(1028, Act, 0, 2, 1),
            ],
            // 5th ACT 27 cycles after window start < tFAW 28 (tRRD still legal)
            mutation: Mutation::ShiftTo { index: 4, cycle: 1027 },
        },
        Case {
            name: "PRE inside write recovery",
            rule: RuleId::Twr,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1011, Wr, 0, 0, 5), ev(1036, Pre, 0, 0, 5)],
            // gap 24 < CWL+BL/2+tWR 25 (tRAS long satisfied)
            mutation: Mutation::ShiftTo { index: 2, cycle: 1035 },
        },
        Case {
            name: "PRE inside read-to-precharge",
            rule: RuleId::Trtp,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1025, Rd, 0, 0, 5), ev(1031, Pre, 0, 0, 5)],
            // gap 5 < tRTP 6 (tRAS satisfied: 30 >= 28)
            mutation: Mutation::ShiftTo { index: 2, cycle: 1030 },
        },
        Case {
            name: "WR-to-RD cross group under tWTR_S",
            rule: RuleId::TwtrS,
            baseline: vec![
                ev(1000, Act, 0, 0, 5),
                ev(1006, Act, 1, 4, 5),
                ev(1020, Wr, 0, 0, 5),
                ev(1035, Rd, 1, 4, 5),
            ],
            // gap 14 < CWL+BL/2+tWTR_S 15
            mutation: Mutation::ShiftTo { index: 3, cycle: 1034 },
        },
        Case {
            name: "WR-to-RD same group under tWTR_L",
            rule: RuleId::TwtrL,
            baseline: vec![
                ev(1000, Act, 0, 0, 5),
                ev(1006, Act, 0, 1, 5),
                ev(1020, Wr, 0, 0, 5),
                ev(1039, Rd, 0, 1, 5),
            ],
            // gap 18 < CWL+BL/2+tWTR_L 19
            mutation: Mutation::ShiftTo { index: 3, cycle: 1038 },
        },
        Case {
            name: "RD-to-WR bus turnaround",
            rule: RuleId::Trtw,
            baseline: vec![
                ev(1000, Act, 0, 0, 5),
                ev(1005, Act, 1, 4, 5),
                ev(1016, Rd, 0, 0, 5),
                ev(1024, Wr, 1, 4, 5),
            ],
            // gap 7 < CL+BL/2+2-CWL 8 (tCCD_S still legal)
            mutation: Mutation::ShiftTo { index: 3, cycle: 1023 },
        },
        Case {
            name: "command inside the tRFC busy window",
            rule: RuleId::Trfc,
            baseline: vec![ev(100, Ref, 0, 0, 0), ev(308, Act, 0, 0, 5)],
            // gap 207 < tRFC 208
            mutation: Mutation::ShiftTo { index: 1, cycle: 307 },
        },
        Case {
            name: "refresh postponed past 9*tREFI",
            rule: RuleId::TrefiMax,
            baseline: vec![ev(100, Ref, 0, 0, 0), ev(400, Ref, 0, 0, 0)],
            // REF gap 56161 > 9*tREFI 56160
            mutation: Mutation::ShiftTo { index: 1, cycle: 56261 },
        },
        Case {
            name: "ACT to a bank whose row is open",
            rule: RuleId::ActOpenBank,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1011, Rd, 0, 0, 5)],
            mutation: Mutation::Insert(ev(1050, Act, 0, 0, 6)),
        },
        Case {
            name: "CAS to a precharged bank",
            rule: RuleId::CasClosedBank,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1011, Rd, 0, 0, 5)],
            // the read lands on a bank that was never activated
            mutation: Mutation::Retarget { index: 1, bank_group: 1, bank: 4 },
        },
        Case {
            name: "CAS row disagrees with the open row",
            rule: RuleId::CasRowMismatch,
            baseline: vec![ev(1000, Act, 0, 0, 5), ev(1011, Rd, 0, 0, 5)],
            mutation: Mutation::SetRow { index: 1, row: 7 },
        },
        Case {
            name: "REF with a row open",
            rule: RuleId::RefOpenBank,
            baseline: vec![
                ev(1000, Act, 0, 0, 5),
                ev(1011, Rd, 0, 0, 5),
                ev(1028, Pre, 0, 0, 5),
                ev(1100, Ref, 0, 0, 0),
            ],
            // drop the precharge: the refresh now hits an open bank
            mutation: Mutation::Remove { index: 2 },
        },
    ]
}

#[test]
fn every_rule_fires_on_exactly_its_corruption() {
    for case in cases() {
        let clean = audit(&case.baseline);
        assert!(
            clean.is_clean() && clean.end_of_stream_check().is_empty(),
            "[{}] baseline must audit clean, got: {:?}",
            case.name,
            clean.violations()
        );

        let mut mutated = case.baseline.clone();
        apply(&mut mutated, case.mutation);
        let aud = audit(&mutated);
        let eos = aud.end_of_stream_check();
        let fired = aud.count(case.rule) > 0 || eos.iter().any(|v| v.rule == case.rule);
        assert!(
            fired,
            "[{}] expected {} to fire, saw {:?} (eos {:?})",
            case.name,
            case.rule.id(),
            aud.violated_rules(),
            eos
        );
        assert!(aud.total_violations() > 0, "[{}] mutation went unnoticed", case.name);
    }
}

#[test]
fn the_case_matrix_covers_every_rule_in_the_book() {
    let mut covered: Vec<RuleId> = cases().iter().map(|c| c.rule).collect();
    covered.sort();
    covered.dedup();
    let missing: Vec<&str> =
        RuleId::ALL.iter().filter(|r| !covered.contains(*r)).map(|r| r.id()).collect();
    assert!(missing.is_empty(), "rules without a mutation case: {missing:?}");
    assert_eq!(covered.len(), RuleId::ALL.len());
}

#[test]
fn end_of_stream_check_catches_a_refreshless_tail() {
    let rb = Rulebook::from_timing(&timing());
    // a single legal ACT, then silence far beyond the refresh horizon
    let late = rb.trefi_max + 10_000;
    let mut a = Auditor::new(&timing(), StreamStart::Complete);
    a.observe(&ev(late, Act, 0, 0, 1));
    assert!(a.is_clean(), "no in-stream rule should fire");
    let eos = a.end_of_stream_check();
    assert_eq!(eos.len(), 1, "tail must violate tREFI_MAX");
    assert_eq!(eos[0].rule, RuleId::TrefiMax);
    assert_eq!(report::status(&a, 0), Status::Violations);
}

/// The zero-false-positive half: a live-armed audit over the full
/// scheduler x engine x builtin-mapping grid (patterns rotating through
/// every address mode and op mix) must certify every run CLEAN.
#[test]
fn armed_audit_certifies_the_scheduler_engine_mapping_grid() {
    let scheds = ["fcfs", "frfcfs", "frfcfs-cap2", "closed", "adaptive"];
    let engines = ["cycle", "event"];
    let maps = ["row_col_bank", "row_bank_col", "bank_row_col", "xor_hash"];
    let patterns = [
        "ADDR=SEQ OP=R BURST=8 BATCH=256",
        "ADDR=BANK SEED=3 OP=W BURST=2 BATCH=192",
        "ADDR=RND SEED=7 OP=M RDPCT=60 BURST=4 BATCH=256",
        "ADDR=STRIDE STRIDE=64k OP=R BURST=4 BATCH=192",
        "ADDR=CHASE SEED=1 WSET=256k BURST=1 BATCH=128",
    ];
    let mut combo = 0usize;
    for sched in scheds {
        for engine in engines {
            for map in maps {
                let pattern = patterns[combo % patterns.len()];
                combo += 1;
                let tokens: Vec<String> = pattern
                    .split_whitespace()
                    .map(str::to_string)
                    .chain([format!("SCHED={sched}"), format!("ENGINE={engine}"), format!("MAP={map}")])
                    .collect();
                let refs: Vec<&str> = tokens.iter().map(String::as_str).collect();
                let cfg = parse_pattern_config(&refs).expect(pattern);
                let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
                platform.enable_audit(0).expect("audit arms on a fresh channel");
                platform.run_batch(0, &cfg).expect(pattern);
                let auditor = platform.auditor(0).expect("armed above");
                assert_eq!(
                    report::status(auditor, 0),
                    Status::Clean,
                    "[{sched}/{engine}/{map}] {pattern}: {:?}",
                    auditor.violations()
                );
                assert!(auditor.events() > 0, "[{sched}/{engine}/{map}] audit saw no commands");
            }
        }
    }
}

/// Randomized half of the same property: random pattern knobs, random
/// grid point, still zero violations.
#[test]
fn prop_armed_audit_is_silent_on_random_legal_traffic() {
    let scheds = ["fcfs", "frfcfs", "frfcfs-cap2", "closed", "adaptive"];
    let engines = ["cycle", "event"];
    let maps = ["row_col_bank", "row_bank_col", "bank_row_col", "xor_hash"];
    let addrs = ["SEQ", "RND", "BANK", "STRIDE"];
    check(
        "armed audit silent on legal traffic",
        24,
        |rng| {
            let addr = addrs[rng.below(addrs.len() as u64) as usize];
            let mut toks = vec![
                format!("ADDR={addr}"),
                format!("SCHED={}", scheds[rng.below(5) as usize]),
                format!("ENGINE={}", engines[rng.below(2) as usize]),
                format!("MAP={}", maps[rng.below(4) as usize]),
                format!("BURST={}", 1 << rng.below(4)),
                format!("BATCH={}", 64 + rng.below(192)),
                format!("SEED={}", rng.below(1 << 20)),
            ];
            match rng.below(3) {
                0 => toks.push("OP=R".into()),
                1 => toks.push("OP=W".into()),
                _ => toks.push(format!("OP=M RDPCT={}", 10 + rng.below(81))),
            }
            if addr == "STRIDE" {
                toks.push(format!("STRIDE={}", 64 << rng.below(8)));
            }
            toks.join(" ")
        },
        |spec| {
            let refs: Vec<&str> = spec.split_whitespace().collect();
            let cfg = parse_pattern_config(&refs).map_err(|e| format!("{spec}: {e}"))?;
            let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
            platform.enable_audit(0).map_err(|e| e.to_string())?;
            platform.run_batch(0, &cfg).map_err(|e| e.to_string())?;
            let auditor = platform.auditor(0).expect("armed above");
            if report::status(auditor, 0) != Status::Clean {
                return Err(format!("violations: {:?}", auditor.violations()));
            }
            Ok(())
        },
    );
}

/// Satellite: ring overflow must surface as TRUNCATED, never as a clean
/// certificate — end to end through the annotated CSV and the offline
/// audit path that `ddr4bench audit` drives.
#[test]
fn overflowed_trace_audits_as_truncated_not_clean() {
    let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    // a 32-event ring is far too small for this batch: the prefix drops
    platform.enable_cmd_trace(0, 32).expect("trace arms");
    let cfg = parse_pattern_config(&["ADDR=BANK", "SEED=2", "BURST=2", "BATCH=512"]).expect("cfg");
    platform.run_batch(0, &cfg).expect("run");
    let trace = platform.cmd_trace(0).expect("armed above");
    assert!(trace.dropped() > 0, "batch must overflow the tiny ring");

    let speed = SpeedBin::Ddr4_1600.name();
    let csv = ddr4bench::obs::export::trace_csv_annotated(speed, &[(0, trace)]);
    assert!(csv.contains("dropped="), "annotated CSV must carry drop metadata: {csv}");

    let parsed = offline::parse_trace_csv(&csv).expect("parses");
    let audits = offline::audit_trace(&parsed, None).expect("audits with embedded speed");
    assert_eq!(audits.len(), 1);
    let a = &audits[0];
    assert!(a.dropped > 0);
    assert_eq!(a.auditor.start(), StreamStart::Truncated);
    let status = report::status(&a.auditor, a.dropped);
    assert_ne!(status, Status::Clean, "a truncated stream must never certify clean");
    let summary = report::summary(&a.auditor, a.channel, a.dropped);
    assert!(summary.contains(&format!("dropped={}", a.dropped)), "{summary}");
    assert!(summary.contains("status=TRUNCATED") || summary.contains("status=VIOLATIONS"), "{summary}");
    let rendered = report::render(&a.auditor, a.channel, a.dropped);
    assert!(rendered.contains("cannot be certified"), "{rendered}");
}

/// The same run captured without overflow round-trips to a CLEAN offline
/// verdict — the offline path agrees with the live auditor.
#[test]
fn unbroken_trace_round_trips_to_a_clean_offline_verdict() {
    let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    platform.enable_cmd_trace(0, ddr4bench::obs::DEFAULT_TRACE_EVENTS).expect("trace arms");
    platform.enable_audit(0).expect("audit arms");
    let cfg = parse_pattern_config(&["ADDR=SEQ", "OP=M", "RDPCT=50", "BURST=4", "BATCH=256"])
        .expect("cfg");
    platform.run_batch(0, &cfg).expect("run");
    assert_eq!(report::status(platform.auditor(0).expect("armed"), 0), Status::Clean);

    let trace = platform.cmd_trace(0).expect("armed above");
    assert_eq!(trace.dropped(), 0);
    let csv = ddr4bench::obs::export::trace_csv_annotated(SpeedBin::Ddr4_1600.name(), &[(0, trace)]);
    let parsed = offline::parse_trace_csv(&csv).expect("parses");
    assert_eq!(parsed.speed, Some(SpeedBin::Ddr4_1600), "speed metadata round-trips");
    let audits = offline::audit_trace(&parsed, None).expect("audits");
    assert_eq!(audits.len(), 1);
    assert_eq!(report::status(&audits[0].auditor, audits[0].dropped), Status::Clean);
    assert_eq!(audits[0].auditor.events(), trace.len() as u64);
}
