//! Integration tests for the PJRT runtime bridge: load the AOT artifacts
//! (built by `make artifacts`), execute them, and assert bit-exact
//! agreement with the pure-Rust mirrors — the contract that lets the TG
//! data path run through XLA.
//!
//! These tests require `artifacts/` to exist (plus the real `xla`
//! bindings instead of the vendored stub); without them each test skips
//! itself, so offline/CI runs stay green.

use ddr4bench::analytic::{predict_gbs, BwFeatures};
use ddr4bench::config::{DesignConfig, OpMix, PatternConfig, SpeedBin};
use ddr4bench::platform::Platform;
use ddr4bench::rng::SplitMix64;
use ddr4bench::runtime::{XlaRuntime, BWMODEL_FEATURES, DATAGEN_BLOCK};
use ddr4bench::trafficgen::payload;

/// Load the AOT runtime, or `None` when the artifact set is absent (the
/// offline/CI configuration) — each test then skips itself. Building the
/// artifacts (`make artifacts` + the real `xla` dependency, see
/// vendor/README.md) turns the whole file back on.
fn runtime() -> Option<XlaRuntime> {
    let dir = ddr4bench::artifacts_dir();
    if !XlaRuntime::artifacts_present(&dir) {
        eprintln!("skipping: artifacts missing in {dir:?} (run `make artifacts`)");
        return None;
    }
    match XlaRuntime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            // Artifacts on disk but no usable PJRT runtime — e.g. the
            // vendored xla stub is still the dependency. Skip, don't fail.
            eprintln!("skipping: artifacts present but runtime unavailable: {e}");
            None
        }
    }
}

#[test]
fn datagen_matches_rust_mirror_exact_block() {
    let Some(rt) = runtime() else { return };
    let seeds: Vec<u32> = (0..DATAGEN_BLOCK as u32).map(|i| i.wrapping_mul(2654435761)).collect();
    let xla = rt.datagen(&seeds).unwrap();
    let rust = payload::expand_batch(&seeds);
    assert_eq!(xla.len(), rust.len());
    assert_eq!(xla, rust, "XLA datagen must be bit-identical to the Rust xorshift mirror");
}

#[test]
fn datagen_handles_partial_and_multi_blocks() {
    let Some(rt) = runtime() else { return };
    for n in [1usize, 7, 100, DATAGEN_BLOCK - 1, DATAGEN_BLOCK + 1, 2 * DATAGEN_BLOCK + 13] {
        let seeds: Vec<u32> = (0..n as u32).map(|i| i ^ 0xABCD_1234).collect();
        let xla = rt.datagen(&seeds).unwrap();
        assert_eq!(xla, payload::expand_batch(&seeds), "n={n}");
    }
}

#[test]
fn datagen_zero_seed_remap_matches() {
    let Some(rt) = runtime() else { return };
    let seeds = vec![0u32, 1, 0, 0xFFFF_FFFF];
    let xla = rt.datagen(&seeds).unwrap();
    assert_eq!(xla, payload::expand_batch(&seeds));
    // zero seeds expand to the remapped golden-ratio stream, never zeros
    assert!(xla.iter().all(|&w| w != 0));
}

#[test]
fn verify_zero_mismatches_on_clean_data() {
    let Some(rt) = runtime() else { return };
    let seeds: Vec<u32> = (1..=1000u32).collect();
    let data = payload::expand_batch(&seeds);
    assert_eq!(rt.verify(&seeds, &data).unwrap(), 0);
}

#[test]
fn verify_counts_planted_faults() {
    let Some(rt) = runtime() else { return };
    let mut rng = SplitMix64::new(99);
    let seeds: Vec<u32> = (0..5000u32).map(|i| i.wrapping_mul(7919)).collect();
    let mut data = payload::expand_batch(&seeds);
    // plant faults at distinct positions
    let mut positions = std::collections::HashSet::new();
    while positions.len() < 37 {
        positions.insert(rng.below(data.len() as u64) as usize);
    }
    for &p in &positions {
        data[p] ^= 1 + (rng.next_u32() & 0xFFFF);
    }
    assert_eq!(rt.verify(&seeds, &data).unwrap(), 37);
    // rust mirror agrees
    assert_eq!(payload::verify_batch(&seeds, &data), 37);
}

#[test]
fn verify_partial_block_padding_correct() {
    let Some(rt) = runtime() else { return };
    // padding rows must contribute exactly zero to the reported count
    for n in [1usize, 3, 511, 4097] {
        let seeds: Vec<u32> = (0..n as u32).map(|i| i + 17).collect();
        let data = payload::expand_batch(&seeds);
        assert_eq!(rt.verify(&seeds, &data).unwrap(), 0, "n={n}");
    }
}

#[test]
fn bwmodel_matches_rust_analytic() {
    let Some(rt) = runtime() else { return };
    assert!(rt.has_bwmodel(), "bwmodel artifact missing");
    // grid over the paper's configuration space
    let mut feats = Vec::new();
    let mut expected = Vec::new();
    for speed in [SpeedBin::Ddr4_1600, SpeedBin::Ddr4_2400] {
        for len in [1u32, 4, 32, 128] {
            for (random, read_frac) in [(false, 1.0f32), (true, 1.0), (false, 0.0), (true, 0.5)] {
                let mut cfg = PatternConfig::seq_read_burst(len, 1);
                cfg.addr = if random {
                    ddr4bench::config::AddrMode::Random { seed: 0 }
                } else {
                    ddr4bench::config::AddrMode::Sequential
                };
                let op = if read_frac >= 0.999 {
                    OpMix::ReadOnly
                } else if read_frac <= 0.001 {
                    OpMix::WriteOnly
                } else {
                    OpMix::Mixed { read_pct: (read_frac * 100.0) as u32 }
                };
                cfg.op = op;
                let f = BwFeatures::from_config(speed, &cfg, 32, 2, 4, 8);
                feats.extend_from_slice(&f.to_row());
                expected.push(predict_gbs(&f, op));
            }
        }
    }
    let preds = rt.bwmodel(&feats).unwrap();
    assert_eq!(preds.len(), expected.len());
    assert_eq!(preds.len() * BWMODEL_FEATURES, feats.len());
    for (i, (p, e)) in preds.iter().zip(expected.iter()).enumerate() {
        let rel = (p - e).abs() / e.max(1e-6);
        assert!(rel < 0.02, "row {i}: XLA {p} vs rust {e} (rel {rel:.4})");
    }
}

#[test]
fn platform_with_runtime_verifies_through_xla() {
    // End-to-end: write-then-read with the XLA data path on, clean memory
    // verifies clean, injected fault is detected — all three layers
    // composing.
    let Some(rt) = runtime() else { return };
    let mut platform =
        Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600)).with_runtime(rt);
    let region = 128 * 4 * 32;
    let mut w = PatternConfig::seq_write_burst(4, 128);
    w.verify = true;
    w.region_bytes = region;
    let ws = platform.run_batch(0, &w).unwrap();
    assert_eq!(ws.counters.mismatches, 0);

    let mut r = PatternConfig::seq_read_burst(4, 128);
    r.verify = true;
    r.region_bytes = region;
    let rs = platform.run_batch(0, &r).unwrap();
    assert_eq!(rs.counters.mismatches, 0, "clean read-back through XLA verify");

    assert!(platform.corrupt(0, 64, 7, 0xDEAD_0000));
    let rs2 = platform.run_batch(0, &r).unwrap();
    assert_eq!(rs2.counters.mismatches, 1, "XLA verify detects the injected fault");
}
