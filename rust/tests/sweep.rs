//! End-to-end tests of the campaign sweep executive: cartesian expansion,
//! parallel execution on the work-stealing pool, artifact emission, and
//! consistency with direct `Platform` runs.

use std::collections::HashSet;

use ddr4bench::config::{PatternConfig, SpeedBin};
use ddr4bench::ddr4::MappingPolicy;
use ddr4bench::platform::sweep::{
    job_csv, job_json, parse_knob_list, parse_mix_list, parse_sched_list, preset, run_sweep,
    summary_json, write_artifacts, SweepSpec,
};
use ddr4bench::platform::Platform;
use ddr4bench::report::compare;

/// A small spec (fast enough for CI) that still exercises two speeds, two
/// channel counts and all three adversarial patterns = 12 jobs.
fn small_grid() -> SweepSpec {
    let mut spec = SweepSpec::paper_grid();
    for (_, cfg) in &mut spec.patterns {
        cfg.batch_len = 64;
    }
    spec
}

#[test]
fn twelve_job_grid_runs_in_parallel() {
    let jobs = small_grid().expand();
    assert_eq!(jobs.len(), 12);
    let outcomes = run_sweep(jobs, 4).unwrap();
    assert_eq!(outcomes.len(), 12);
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.job.id, i);
        let c = &o.agg.counters;
        assert_eq!(
            c.rd_txns + c.wr_txns,
            64 * o.job.channels as u64,
            "job {i} ({}) conserves transactions across {} channel(s)",
            o.job.label,
            o.job.channels
        );
        assert!(o.agg.total_throughput_gbs() > 0.0, "job {i} moved data");
    }
    // the grid really covers the cartesian product
    let speeds: HashSet<u32> = outcomes.iter().map(|o| o.job.speed.data_rate_mts()).collect();
    let channels: HashSet<usize> = outcomes.iter().map(|o| o.job.channels).collect();
    let labels: HashSet<&str> = outcomes.iter().map(|o| o.job.label.as_str()).collect();
    assert_eq!(speeds, HashSet::from([1600, 2400]));
    assert_eq!(channels, HashSet::from([1, 2]));
    assert_eq!(labels, HashSet::from(["strided", "bank", "chase"]));
}

#[test]
fn sweep_matches_direct_platform_run() {
    // The executive adds orchestration, not measurement: a sweep job's
    // numbers equal a direct run of the same (design, pattern) point.
    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_1600];
    spec.channels = vec![1];
    spec.patterns = vec![("strided".into(), PatternConfig::strided_read(64 << 10, 4, 256))];
    let outcomes = run_sweep(spec.expand(), 2).unwrap();
    assert_eq!(outcomes.len(), 1);

    let mut p = Platform::new(ddr4bench::config::DesignConfig::single_channel(
        SpeedBin::Ddr4_1600,
    ));
    let direct = p.run_batch(0, &PatternConfig::strided_read(64 << 10, 4, 256)).unwrap();
    let (a, b) = (outcomes[0].agg.read_throughput_gbs(), direct.read_throughput_gbs());
    assert!((a - b).abs() / b < 1e-9, "sweep {a} vs direct {b}");
}

#[test]
fn worker_count_does_not_change_results() {
    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_1600];
    let serial = run_sweep(spec.expand(), 1).unwrap();
    let parallel = run_sweep(spec.expand(), 8).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.job.id, p.job.id);
        assert_eq!(s.agg.counters.rd_txns, p.agg.counters.rd_txns);
        assert_eq!(s.agg.counters.rd_bytes, p.agg.counters.rd_bytes);
        assert_eq!(s.agg.counters.total_cycles, p.agg.counters.total_cycles);
    }
}

#[test]
fn artifacts_written_one_json_and_csv_per_job() {
    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_2400];
    spec.channels = vec![1];
    let outcomes = run_sweep(spec.expand(), 3).unwrap();
    let dir = std::env::temp_dir().join(format!("ddr4bench_sweep_test_{}", std::process::id()));
    let summary = write_artifacts(&outcomes, &dir).unwrap();
    assert!(summary.ends_with("BENCH_sweep.json"));
    let summary_text = std::fs::read_to_string(&summary).unwrap();
    assert!(summary_text.contains("\"schema\": \"ddr4bench.sweep.v4\""));
    let mut jsons = 0;
    let mut csvs = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        match path.extension().and_then(|e| e.to_str()) {
            Some("json") if path != summary => {
                jsons += 1;
                let text = std::fs::read_to_string(&path).unwrap();
                assert!(text.contains("\"total_gbs\""), "{path:?}");
            }
            Some("csv") => {
                csvs += 1;
                let text = std::fs::read_to_string(&path).unwrap();
                assert_eq!(text.lines().count(), 2, "{path:?}: header + one row");
            }
            _ => {}
        }
    }
    assert_eq!(jsons, outcomes.len(), "one JSON per job");
    assert_eq!(csvs, outcomes.len(), "one CSV per job");
    // summary embeds every job
    for o in &outcomes {
        assert!(summary_text.contains(&format!("\"id\": {}", o.job.id)));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mapping_and_knob_axes_run_and_label_artifacts() {
    // 1 speed x 1 channel x 2 mappings x 2 knob profiles x 1 pattern
    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_1600];
    spec.channels = vec![1];
    spec.mappings = vec![MappingPolicy::row_col_bank(), MappingPolicy::xor_hash()];
    spec.knobs = parse_knob_list("lookahead=1,lookahead=8").unwrap();
    spec.patterns = vec![preset("bank").unwrap()];
    spec.patterns[0].1.batch_len = 32;
    let jobs = spec.expand();
    assert_eq!(jobs.len(), 4);
    let outcomes = run_sweep(jobs, 2).unwrap();
    for o in &outcomes {
        let c = &o.agg.counters;
        assert_eq!(c.rd_txns + c.wr_txns, 32, "{} conserves txns", o.job.mapping);
        assert!(o.agg.total_throughput_gbs() > 0.0);
        let j = job_json(o);
        assert!(j.contains(&format!("\"mapping\": \"{}\"", o.job.mapping.name())), "{j}");
        assert!(j.contains(&format!("\"knobs\": \"{}\"", o.job.knob)), "{j}");
    }
    // per-job artifacts are labeled with the policy and knob profile
    let dir = std::env::temp_dir().join(format!("ddr4bench_map_sweep_{}", std::process::id()));
    let summary = write_artifacts(&outcomes, &dir).unwrap();
    let names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    for (map, knob) in [("row_col_bank", "lookahead1"), ("xor_hash", "lookahead8")] {
        assert!(
            names.iter().any(|n| n.contains(map) && n.contains(knob) && n.ends_with(".json")),
            "missing {map}/{knob} artifact in {names:?}"
        );
    }
    // and the summary feeds straight into the compare pipeline
    let loaded = compare::load_sweep(&summary).unwrap();
    assert_eq!(loaded.records.len(), 4);
    let maps: HashSet<&str> = loaded.records.iter().map(|r| r.mapping.as_str()).collect();
    assert_eq!(maps, HashSet::from(["row_col_bank", "xor_hash"]));
    let report = compare::compare(&[loaded.clone(), loaded.clone()], 2.0);
    assert_eq!(report.delta.rows.len(), 4);
    assert!(report.regressions.is_empty(), "a sweep never regresses against itself");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sched_axis_sweep_labels_artifacts_and_orders_policies_sanely() {
    // The ISSUE acceptance run at test scale:
    //   ddr4bench sweep --scheds fcfs,frfcfs,frfcfs-cap,closed
    // on a bank-conflict pattern (every access a same-bank row miss) and
    // a sequential pattern (pure row-hit locality).
    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_1600];
    spec.channels = vec![1];
    spec.scheds = parse_sched_list("fcfs,frfcfs,frfcfs-cap,closed").unwrap();
    spec.patterns = vec![preset("bank").unwrap(), preset("seq").unwrap()];
    for (_, cfg) in &mut spec.patterns {
        cfg.batch_len = 128;
    }
    let jobs = spec.expand();
    assert_eq!(jobs.len(), 4 * 2, "4 policies x 2 patterns");
    let outcomes = run_sweep(jobs, 4).unwrap();
    let gbs = |sched: &str, pattern: &str| -> f64 {
        outcomes
            .iter()
            .find(|o| o.job.sched.name() == sched && o.job.label == pattern)
            .unwrap_or_else(|| panic!("missing {sched}/{pattern}"))
            .agg
            .total_throughput_gbs()
    };
    // sane ordering: the reordering scheduler cannot lose to strict FCFS
    // on an adversarial bank-conflict stream...
    assert!(
        gbs("frfcfs", "bank") >= gbs("fcfs", "bank") * 0.999,
        "frfcfs {} vs fcfs {} on bank conflicts",
        gbs("frfcfs", "bank"),
        gbs("fcfs", "bank")
    );
    // ...and open page cannot lose to closed page on a sequential stream
    assert!(
        gbs("frfcfs", "seq") >= gbs("closed", "seq") * 0.999,
        "frfcfs {} vs closed {} on sequential",
        gbs("frfcfs", "seq"),
        gbs("closed", "seq")
    );
    // policy-labeled artifacts: stem carries the sched axis, JSON/CSV
    // carry the sched field
    let dir = std::env::temp_dir().join(format!("ddr4bench_sched_sweep_{}", std::process::id()));
    let summary = write_artifacts(&outcomes, &dir).unwrap();
    let names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    for sched in ["fcfs", "frfcfs", "frfcfs-cap", "closed"] {
        assert!(
            names.iter().any(|n| n.contains(sched) && n.ends_with(".json")),
            "missing {sched} artifact in {names:?}"
        );
    }
    for o in &outcomes {
        let j = job_json(o);
        assert!(j.contains(&format!("\"sched\": \"{}\"", o.job.sched.name())), "{j}");
        assert!(job_csv(o).contains(&o.job.sched.name()), "csv carries the policy");
    }
    // the summary round-trips through the compare pipeline with the
    // sched axis as part of the matching key
    let loaded = compare::load_sweep(&summary).unwrap();
    assert_eq!(loaded.records.len(), 8);
    let scheds: HashSet<&str> = loaded.records.iter().map(|r| r.sched.as_str()).collect();
    assert_eq!(scheds, HashSet::from(["fcfs", "frfcfs", "frfcfs-cap", "closed"]));
    assert!(loaded.records.iter().all(|r| r.rd_p99_ns.is_some()), "percentiles in artifacts");
    let report = compare::compare(&[loaded.clone(), loaded.clone()], 2.0);
    assert_eq!(report.delta.rows.len(), 8);
    assert!(report.regressions.is_empty(), "a sweep never regresses against itself");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn heterogeneous_mix_sweep_end_to_end() {
    // The mixes axis: one 3-channel heterogeneous mix next to a uniform
    // pattern, through execution, artifacts and the compare pipeline.
    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_1600];
    spec.channels = vec![1];
    spec.patterns = vec![preset("seq").unwrap()];
    spec.patterns[0].1.batch_len = 32;
    spec.mixes = parse_mix_list(
        "0:SEQ,BURST=32,BATCH=64+1:CHASE,WSET=64k,BURST=1,BATCH=32+2:BANK,SEED=1,BATCH=32",
    )
    .unwrap();
    let jobs = spec.expand();
    assert_eq!(jobs.len(), 2, "1 uniform pattern + 1 mix");
    let outcomes = run_sweep(jobs, 2).unwrap();
    let mix = outcomes.iter().find(|o| o.job.mix.is_some()).unwrap();
    assert_eq!(mix.job.channels, 3, "mix brings its own channel count");
    assert_eq!(mix.job.label, "seq+chase+bank");
    assert_eq!(mix.per_channel.len(), 3);
    // distinct per-channel workloads produce distinct per-channel stats
    let seq = mix.per_channel[0].read_throughput_gbs();
    let chase = mix.per_channel[1].read_throughput_gbs();
    assert!(seq > 2.0 * chase, "seq {seq:.2} vs chase {chase:.2}");
    // artifacts: v4 schema, mix spec in JSON and (quoted) in CSV
    let dir = std::env::temp_dir().join(format!("ddr4bench_mix_sweep_{}", std::process::id()));
    let summary = write_artifacts(&outcomes, &dir).unwrap();
    let names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert!(
        names.iter().any(|n| n.contains("seq_chase_bank") && n.ends_with(".json")),
        "mix-labeled artifact in {names:?}"
    );
    // the v4 summary loads in compare, with the mix spec in the job key
    let loaded = compare::load_sweep(&summary).unwrap();
    assert_eq!(loaded.records.len(), 2);
    let mix_rec = loaded.records.iter().find(|r| !r.mix.is_empty()).unwrap();
    assert_eq!(mix_rec.pattern, "seq+chase+bank");
    assert!(mix_rec.mix.contains("1:") && mix_rec.mix.contains("ADDR=CHASE"), "{}", mix_rec.mix);
    let report = compare::compare(&[loaded.clone(), loaded.clone()], 2.0);
    assert_eq!(report.delta.rows.len(), 2);
    assert!(report.regressions.is_empty());
    // determinism: a second independently-scheduled run reproduces the
    // mix job exactly
    let again = run_sweep(spec.expand(), 1).unwrap();
    let mix2 = again.iter().find(|o| o.job.mix.is_some()).unwrap();
    assert_eq!(
        mix.agg.counters.total_cycles, mix2.agg.counters.total_cycles,
        "run-to-run determinism on the mix job"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn audited_sweep_certifies_clean_and_writes_audit_artifacts() {
    // The CI legality gate at test scale: arm the independent protocol
    // auditor across a grid and require every job to come back CLEAN,
    // with a per-job certificate artifact.
    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_1600];
    spec.channels = vec![1, 2];
    spec.scheds = parse_sched_list("fcfs,frfcfs,closed").unwrap();
    spec.patterns = vec![preset("bank").unwrap(), preset("seq").unwrap()];
    for (_, cfg) in &mut spec.patterns {
        cfg.batch_len = 64;
    }
    spec.audit = true;
    let outcomes = run_sweep(spec.expand(), 4).unwrap();
    assert_eq!(outcomes.len(), 2 * 3 * 2);
    for o in &outcomes {
        let audit = o.audit.as_ref().expect("audited job carries a certificate");
        assert!(audit.contains("status=CLEAN"), "job {}: {audit}", o.job.label);
        assert!(audit.contains("violations=0"), "job {}: {audit}", o.job.label);
    }
    let dir = std::env::temp_dir().join(format!("ddr4bench_audit_sweep_{}", std::process::id()));
    let _summary = write_artifacts(&outcomes, &dir).unwrap();
    let audits = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().ends_with("_audit.txt")
        })
        .count();
    assert_eq!(audits, outcomes.len(), "one audit certificate per job");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn audit_spec_key_parses_and_unaudited_jobs_carry_no_certificate() {
    let spec = SweepSpec::parse("speeds = 1600\nchannels = 1\naudit = on\n").unwrap();
    assert!(spec.audit);
    let spec = SweepSpec::parse("audit = off\n").unwrap();
    assert!(!spec.audit);
    assert!(SweepSpec::parse("audit = maybe\n").is_err());

    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_1600];
    spec.channels = vec![1];
    spec.patterns = vec![preset("seq").unwrap()];
    spec.patterns[0].1.batch_len = 32;
    let outcomes = run_sweep(spec.expand(), 1).unwrap();
    assert!(outcomes[0].audit.is_none(), "audit off by default");
}

#[test]
fn summary_and_job_renderers_agree() {
    let mut spec = small_grid();
    spec.speeds = vec![SpeedBin::Ddr4_1600];
    spec.channels = vec![1];
    spec.patterns = vec![preset("bank").unwrap()];
    spec.patterns[0].1.batch_len = 32;
    let outcomes = run_sweep(spec.expand(), 1).unwrap();
    let j = job_json(&outcomes[0]);
    let s = summary_json(&outcomes, "test-run");
    assert!(s.contains("\"source\": \"test-run\""));
    // every key of the job object appears in the summary's embedded copy
    for key in ["\"pattern\"", "\"rd_gbs\"", "\"wall_ms\"", "\"per_channel_total_gbs\""] {
        assert!(j.contains(key) && s.contains(key), "{key}");
    }
    let c = job_csv(&outcomes[0]);
    assert!(c.starts_with("id,speed,"));
}
