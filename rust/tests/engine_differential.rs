//! Differential pinning of the event-driven time-skip engine against the
//! frozen cycle-stepped oracle.
//!
//! The event engine may only leap over fabric cycles in which the
//! canonical loop body is provably a no-op, so *every* observable — the
//! hardware counters (including both latency histograms), the derived
//! latency percentiles, the per-device command statistics (compared
//! through the deterministic energy breakdown they feed), and the
//! windowed telemetry series when a `TELEM=` sampler is armed — must be
//! bit-identical across engines for any workload, scheduler, address
//! mapping, and heterogeneous channel mix. Randomized patterns come from
//! the seeded in-tree property kit (`DDR4BENCH_PT_SEED` reproduces a
//! failing run exactly).

use ddr4bench::config::{
    AddrMode, ChannelMix, DesignConfig, EngineKind, PatternConfig, SchedKind, Signaling, SpeedBin,
};
use ddr4bench::ddr4::MappingPolicy;
use ddr4bench::platform::Platform;
use ddr4bench::rng::SplitMix64;
use ddr4bench::stats::BatchStats;
use ddr4bench::testkit::check;

/// Draw a randomized pattern across the whole access-pattern engine:
/// every address mode, a spread of burst/batch sizes, and (30% of the
/// time) blocking signaling — the idle-heavy regime where the event
/// engine leaps hardest.
fn random_pattern(rng: &mut SplitMix64) -> PatternConfig {
    let batch = 64 + rng.below(192) as u32;
    let burst = [1u32, 4, 8, 32][rng.below(4) as usize];
    let mut cfg = match rng.below(6) {
        0 => PatternConfig::seq_read_burst(burst, batch),
        1 => PatternConfig::rnd_read_burst(burst, batch, rng.next_u64()),
        2 => PatternConfig::bank_conflict_read(1, batch, rng.next_u64()),
        3 => {
            PatternConfig::pointer_chase_read(1 << 18, 64 + rng.below(64) as u32, rng.next_u64())
        }
        4 => PatternConfig::strided_read(64 << 10, burst, batch),
        _ => PatternConfig::mixed(AddrMode::Sequential, burst, batch),
    };
    if rng.percent(30) {
        cfg.signaling = Signaling::Blocking;
    }
    if rng.percent(50) {
        // arm the telemetry sampler on half the draws: the differential
        // then also pins the windowed series bit-identical across engines
        cfg.telemetry = Some(64 << rng.below(4));
    }
    cfg
}

/// Every observable of two batches must match bit for bit.
fn assert_same(a: &BatchStats, b: &BatchStats, what: &str) -> Result<(), String> {
    if a.counters != b.counters {
        return Err(format!(
            "{what}: counters diverge\n  cycle: {:?}\n  event: {:?}",
            a.counters, b.counters
        ));
    }
    if a.telemetry != b.telemetry {
        return Err(format!(
            "{what}: telemetry series diverge\n  cycle: {:?}\n  event: {:?}",
            a.telemetry, b.telemetry
        ));
    }
    for pct in [50.0, 90.0, 95.0, 99.0] {
        let (ra, rb) = (a.read_latency_pct_ns(pct), b.read_latency_pct_ns(pct));
        if ra.to_bits() != rb.to_bits() {
            return Err(format!("{what}: read p{pct} diverges ({ra} vs {rb})"));
        }
        let (wa, wb) = (a.write_latency_pct_ns(pct), b.write_latency_pct_ns(pct));
        if wa.to_bits() != wb.to_bits() {
            return Err(format!("{what}: write p{pct} diverges ({wa} vs {wb})"));
        }
    }
    // the energy breakdown is a pure function of the per-device command
    // stats delta (ACT/PRE/RD/WR/REF counts) and the batch's DRAM-cycle
    // span: bit-equality here pins both, without platform internals
    let ea = [
        a.energy.activate_nj,
        a.energy.read_nj,
        a.energy.write_nj,
        a.energy.refresh_nj,
        a.energy.background_nj,
    ];
    let eb = [
        b.energy.activate_nj,
        b.energy.read_nj,
        b.energy.write_nj,
        b.energy.refresh_nj,
        b.energy.background_nj,
    ];
    if ea.iter().zip(&eb).any(|(x, y)| x.to_bits() != y.to_bits()) {
        return Err(format!("{what}: device-stat-derived energy diverges ({ea:?} vs {eb:?})"));
    }
    Ok(())
}

/// Run `cfg` on a cycle-engine platform and an event-engine platform —
/// two batches each, so the second starts on a nonzero, engine-advanced
/// channel clock — and compare every observable.
fn run_differential(
    cfg: &PatternConfig,
    sched: SchedKind,
    mapping: MappingPolicy,
) -> Result<(), String> {
    let mut design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
    design.controller.sched = sched;
    design.geometry.mapping = mapping;
    let mut cycle = Platform::new(design.clone());
    design.engine = EngineKind::Event;
    let mut event = Platform::new(design);
    for batch in 0..2 {
        let a = cycle.run_batch(0, cfg).map_err(|e| e.to_string())?;
        let b = event.run_batch(0, cfg).map_err(|e| e.to_string())?;
        assert_same(&a, &b, &format!("batch {batch}"))?;
    }
    Ok(())
}

#[test]
fn event_engine_bit_identical_across_all_schedulers() {
    check("engine differential across schedulers", 4, random_pattern, |cfg| {
        for sched in SchedKind::ALL {
            run_differential(cfg, sched, MappingPolicy::row_col_bank())
                .map_err(|e| format!("{sched}: {e}"))?;
        }
        Ok(())
    })
}

#[test]
fn event_engine_bit_identical_across_mappings() {
    check("engine differential across mappings", 3, random_pattern, |cfg| {
        for mapping in MappingPolicy::builtins() {
            run_differential(cfg, SchedKind::FrFcfs, mapping)
                .map_err(|e| format!("{mapping}: {e}"))?;
        }
        Ok(())
    })
}

#[test]
fn event_engine_bit_identical_under_deep_queue_knobs() {
    // The engines must stay locked when the controller runs wide
    // reorder windows over deep saturated queues — the regime the
    // indexed scheduler fast path exists for: lookahead up to 32,
    // depth-64 queues, bank-conflict and pointer-chase streams (plus a
    // mixed read/write stream so the write queue saturates too).
    check(
        "engine differential, deep-queue knobs",
        3,
        |rng| {
            let batch = 192 + rng.below(64) as u32;
            let mut cfg = match rng.below(3) {
                0 => PatternConfig::bank_conflict_read(1, batch, rng.next_u64()),
                1 => PatternConfig::pointer_chase_read(1 << 16, batch, rng.next_u64()),
                _ => PatternConfig::mixed(AddrMode::Sequential, 4, batch),
            };
            if rng.percent(40) {
                cfg.telemetry = Some(256);
            }
            let lookahead = [8usize, 32][rng.below(2) as usize];
            (cfg, lookahead)
        },
        |(cfg, lookahead)| {
            let mut design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
            design.controller.lookahead = *lookahead;
            design.controller.read_queue_depth = 64;
            design.controller.write_queue_depth = 64;
            design.controller.write_drain_high = 48;
            design.controller.write_drain_low = 8;
            let mut cycle = Platform::new(design.clone());
            design.engine = EngineKind::Event;
            let mut event = Platform::new(design);
            for batch in 0..2 {
                let a = cycle.run_batch(0, cfg).map_err(|e| e.to_string())?;
                let b = event.run_batch(0, cfg).map_err(|e| e.to_string())?;
                assert_same(&a, &b, &format!("deep-queue batch {batch}"))?;
            }
            Ok(())
        },
    )
}

#[test]
fn event_engine_bit_identical_on_channel_mixes() {
    check(
        "engine differential across channel mixes",
        4,
        |rng| {
            let n = 2 + rng.below(2) as usize; // 2 or 3 channels
            (0..n).map(|_| random_pattern(rng)).collect::<Vec<_>>()
        },
        |cfgs| {
            let mix = ChannelMix::new(cfgs.clone()).map_err(|e| e.to_string())?;
            let mut design = DesignConfig::with_channels(cfgs.len(), SpeedBin::Ddr4_1600);
            let mut cycle = Platform::new(design.clone());
            design.engine = EngineKind::Event;
            let mut event = Platform::new(design);
            let a = cycle.run_batch_mix(&mix).map_err(|e| e.to_string())?;
            let b = event.run_batch_mix(&mix).map_err(|e| e.to_string())?;
            for (ch, (sa, sb)) in a.iter().zip(&b).enumerate() {
                assert_same(sa, sb, &format!("channel {ch}"))?;
            }
            Ok(())
        },
    )
}

#[test]
fn engine_override_token_matches_design_level_selection() {
    // a per-batch ENGINE=event override on a cycle-default platform must
    // agree with the cycle oracle just like a design-level selection
    check("engine differential via ENGINE= override", 3, random_pattern, |cfg| {
        let design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
        let mut base = Platform::new(design.clone());
        let mut ovr = Platform::new(design);
        let a = base.run_batch(0, cfg).map_err(|e| e.to_string())?;
        let mut cfg2 = cfg.clone();
        cfg2.engine = Some(EngineKind::Event);
        let b = ovr.run_batch(0, &cfg2).map_err(|e| e.to_string())?;
        assert_same(&a, &b, "override batch")
    })
}
