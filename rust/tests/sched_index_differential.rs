//! Differential pinning of the indexed scheduler fast path against the
//! frozen scan oracle (`ControllerParams::sched_oracle`).
//!
//! Three layers of evidence, all seeded through the in-tree property
//! kit (`DDR4BENCH_PT_SEED` reproduces any failing run exactly):
//!
//! 1. **Controller-level, command for command.** Two `MemController`s
//!    differing only in the `sched_oracle` flag are driven with
//!    identical pushes at identical cycles; every tick's issued command
//!    and every completion must match bit-exactly, for every policy,
//!    across knob profiles and adversarial address streams, with the
//!    incremental indexes recounted from scratch along the way.
//! 2. **Platform-level, every observable.** Whole-platform runs (both
//!    simulation engines, every built-in address mapping) must produce
//!    bit-identical counters, telemetry series, latency percentiles and
//!    device-stat-derived energy whichever scheduler implementation is
//!    selected.
//! 3. **Wake conservatism.** Whenever the indexed controller's tick
//!    fast path decides to sleep to `idle_until`, a scan-oracle clone
//!    forced to evaluate inside the skipped window must issue nothing —
//!    the sleep never runs past the first cycle the oracle would act on.

use ddr4bench::config::{
    AddrMode, ControllerParams, DesignConfig, EngineKind, PatternConfig, SchedKind, SpeedBin,
};
use ddr4bench::controller::{Completion, MemController, MemRequest};
use ddr4bench::ddr4::{Cycle, DramGeometry, MappingPolicy, TimingParams};
use ddr4bench::platform::Platform;
use ddr4bench::rng::SplitMix64;
use ddr4bench::stats::BatchStats;
use ddr4bench::testkit::check;

// ------------------------------------------------------------------------
// Controller-level differential: indexed vs oracle, tick for tick
// ------------------------------------------------------------------------

/// Address streams for the controller-level driver (mirrors the
/// generator in `frfcfs_differential.rs`; test binaries cannot share
/// code). `Chase` is the duplicate-address stress case for the indexed
/// occupancy paths; `BankConflict` keeps one bank's row buffer thrashing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AddrStream {
    /// Small same-address pool mixed with uniform addresses.
    Mixed,
    /// Every request in one bank, hopping across its rows.
    BankConflict,
    /// Multiplicative walk over a small region (pointer-chase-like).
    Chase,
}

struct StreamGen {
    stream: AddrStream,
    pool: Vec<u64>,
    row_step: u64,
    cursor: u64,
}

impl StreamGen {
    fn new(stream: AddrStream, geo: &DramGeometry, seed: u64) -> Self {
        Self {
            stream,
            pool: (0..8).map(|i| i * 64).collect(),
            row_step: geo.row_step_bytes(),
            cursor: seed | 1,
        }
    }

    fn next(&mut self, rng: &mut SplitMix64) -> u64 {
        match self.stream {
            AddrStream::Mixed => {
                if rng.percent(20) {
                    self.pool[rng.below(self.pool.len() as u64) as usize]
                } else {
                    rng.below(1 << 22) * 64
                }
            }
            AddrStream::BankConflict => rng.below(1 << 9) * self.row_step,
            AddrStream::Chase => {
                self.cursor = self.cursor.wrapping_mul(6364136223846793005).wrapping_add(1);
                (self.cursor >> 16) % (1 << 12) * 64
            }
        }
    }
}

/// Drive an indexed controller and a scan-oracle controller with an
/// identical randomized request stream; compare every tick's command,
/// every completion, and the final controller/device statistics. The
/// indexed controller's incremental indexes are also recounted from
/// scratch periodically.
fn run_controller_differential(
    seed: u64,
    params: ControllerParams,
    cycles: u64,
    stream: AddrStream,
    push_pct: u32,
) -> Result<(), String> {
    let geo = DramGeometry::profpga_board();
    let timing = TimingParams::for_bin(SpeedBin::Ddr4_1600);
    let idx_params = ControllerParams { sched_oracle: false, ..params };
    let ora_params = ControllerParams { sched_oracle: true, ..params };
    let mut indexed = MemController::new(idx_params, timing, geo);
    let mut oracle = MemController::new(ora_params, timing, geo);
    let mut rng = SplitMix64::new(seed);
    let mut gen = StreamGen::new(stream, &geo, seed);
    let mut id = 0u64;
    let mut done_idx: Vec<Completion> = Vec::new();
    let mut done_ora: Vec<Completion> = Vec::new();
    for now in 0..cycles {
        if rng.percent(push_pct) {
            let is_write = rng.percent(40);
            let addr = gen.next(&mut rng);
            let req = MemRequest {
                txn_id: id,
                is_write,
                addr: geo.decode(addr),
                burst_addr: addr,
                beats: 2,
                arrival: now,
                last_of_txn: true,
            };
            let a = indexed.try_push(req);
            let b = oracle.try_push(req);
            if a.is_ok() != b.is_ok() {
                return Err(format!(
                    "cycle {now}: push divergence (indexed {:?} vs oracle {:?})",
                    a.is_ok(),
                    b.is_ok()
                ));
            }
            if a.is_ok() {
                id += 1;
            }
        }
        let ca = indexed.tick(now);
        let cb = oracle.tick(now);
        if ca != cb {
            return Err(format!("cycle {now}: command divergence {ca:?} vs {cb:?}"));
        }
        indexed.pop_completions(now, &mut done_idx);
        oracle.pop_completions(now, &mut done_ora);
        if done_idx.len() != done_ora.len() {
            return Err(format!(
                "cycle {now}: completion count divergence {} vs {}",
                done_idx.len(),
                done_ora.len()
            ));
        }
        if now % 1024 == 0 {
            indexed.debug_assert_index_consistent();
        }
    }
    if done_idx != done_ora {
        return Err("completion streams diverge".into());
    }
    if done_idx.is_empty() {
        return Err("differential run serviced no requests".into());
    }
    let (si, so) = (indexed.stats(), oracle.stats());
    if si.refresh_stall_cycles != so.refresh_stall_cycles
        || si.mode_switches != so.mode_switches
        || si.queue_rejects != so.queue_rejects
    {
        return Err(format!("controller stats diverge\n  indexed: {si:?}\n  oracle: {so:?}"));
    }
    if indexed.device().stats() != oracle.device().stats() {
        return Err(format!(
            "device command stats diverge\n  indexed: {:?}\n  oracle: {:?}",
            indexed.device().stats(),
            oracle.device().stats()
        ));
    }
    Ok(())
}

#[test]
fn indexed_scheduler_matches_scan_oracle_for_every_policy() {
    check(
        "sched index differential across policies and knobs",
        5,
        |rng| {
            let lookahead = [1usize, 4, 8, 32][rng.below(4) as usize];
            let idle = [0u32, 64][rng.below(2) as usize];
            let dwell = [8u32, 48][rng.below(2) as usize];
            let stream = [AddrStream::Mixed, AddrStream::BankConflict, AddrStream::Chase]
                [rng.below(3) as usize];
            (rng.next_u64(), lookahead, idle, dwell, stream)
        },
        |&(seed, lookahead, idle, dwell, stream)| {
            for sched in SchedKind::ALL {
                let params = ControllerParams {
                    sched,
                    lookahead,
                    idle_precharge_cycles: idle,
                    mode_dwell_ck: dwell,
                    ..Default::default()
                };
                run_controller_differential(seed, params, 25_000, stream, 60)
                    .map_err(|e| format!("{sched}: {e}"))?;
            }
            Ok(())
        },
    )
}

#[test]
fn indexed_scheduler_matches_scan_oracle_on_deep_saturated_queues() {
    // the regime the indexes exist for: depth-64 queues kept brimming
    // under wide reorder windows, on the adversarial streams
    check(
        "sched index differential, deep saturated queues",
        4,
        |rng| {
            let lookahead = [8usize, 32][rng.below(2) as usize];
            let stream = [AddrStream::Mixed, AddrStream::BankConflict, AddrStream::Chase]
                [rng.below(3) as usize];
            (rng.next_u64(), lookahead, stream)
        },
        |&(seed, lookahead, stream)| {
            for sched in SchedKind::ALL {
                let params = ControllerParams {
                    sched,
                    lookahead,
                    read_queue_depth: 64,
                    write_queue_depth: 64,
                    write_drain_high: 48,
                    write_drain_low: 8,
                    ..Default::default()
                };
                run_controller_differential(seed, params, 30_000, stream, 90)
                    .map_err(|e| format!("{sched}: {e}"))?;
            }
            Ok(())
        },
    )
}

// ------------------------------------------------------------------------
// Platform-level differential: every observable, both engines
// ------------------------------------------------------------------------

/// Every observable of two batches must match bit for bit (same contract
/// as the engine differential: counters, telemetry, percentiles through
/// their bit patterns, and the device-stat-derived energy breakdown).
fn assert_same(a: &BatchStats, b: &BatchStats, what: &str) -> Result<(), String> {
    if a.counters != b.counters {
        return Err(format!(
            "{what}: counters diverge\n  indexed: {:?}\n  oracle: {:?}",
            a.counters, b.counters
        ));
    }
    if a.telemetry != b.telemetry {
        return Err(format!(
            "{what}: telemetry series diverge\n  indexed: {:?}\n  oracle: {:?}",
            a.telemetry, b.telemetry
        ));
    }
    for pct in [50.0, 90.0, 95.0, 99.0] {
        let (ra, rb) = (a.read_latency_pct_ns(pct), b.read_latency_pct_ns(pct));
        if ra.to_bits() != rb.to_bits() {
            return Err(format!("{what}: read p{pct} diverges ({ra} vs {rb})"));
        }
        let (wa, wb) = (a.write_latency_pct_ns(pct), b.write_latency_pct_ns(pct));
        if wa.to_bits() != wb.to_bits() {
            return Err(format!("{what}: write p{pct} diverges ({wa} vs {wb})"));
        }
    }
    let ea = [
        a.energy.activate_nj,
        a.energy.read_nj,
        a.energy.write_nj,
        a.energy.refresh_nj,
        a.energy.background_nj,
    ];
    let eb = [
        b.energy.activate_nj,
        b.energy.read_nj,
        b.energy.write_nj,
        b.energy.refresh_nj,
        b.energy.background_nj,
    ];
    if ea.iter().zip(&eb).any(|(x, y)| x.to_bits() != y.to_bits()) {
        return Err(format!("{what}: device-stat-derived energy diverges ({ea:?} vs {eb:?})"));
    }
    Ok(())
}

/// Run `cfg` on an indexed platform and a scan-oracle platform — two
/// batches each, so the second starts on an engine-advanced clock — and
/// compare every observable.
fn run_platform_differential(
    cfg: &PatternConfig,
    sched: SchedKind,
    mapping: MappingPolicy,
    engine: EngineKind,
    lookahead: usize,
) -> Result<(), String> {
    let mut design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
    design.controller.sched = sched;
    design.controller.lookahead = lookahead;
    design.controller.read_queue_depth = 64;
    design.controller.write_queue_depth = 64;
    design.controller.write_drain_high = 48;
    design.controller.write_drain_low = 8;
    design.geometry.mapping = mapping;
    design.engine = engine;
    let mut indexed = Platform::new(design.clone());
    design.controller.sched_oracle = true;
    let mut oracle = Platform::new(design);
    for batch in 0..2 {
        let a = indexed.run_batch(0, cfg).map_err(|e| e.to_string())?;
        let b = oracle.run_batch(0, cfg).map_err(|e| e.to_string())?;
        assert_same(&a, &b, &format!("batch {batch}"))?;
    }
    Ok(())
}

/// Deep-queue-leaning pattern draw for the platform differential.
fn deep_pattern(rng: &mut SplitMix64) -> (PatternConfig, usize) {
    let batch = 128 + rng.below(128) as u32;
    let mut cfg = match rng.below(3) {
        0 => PatternConfig::bank_conflict_read(1, batch, rng.next_u64()),
        1 => PatternConfig::pointer_chase_read(1 << 16, batch, rng.next_u64()),
        _ => PatternConfig::mixed(AddrMode::Sequential, 4, batch),
    };
    if rng.percent(40) {
        cfg.telemetry = Some(256);
    }
    let lookahead = [8usize, 32][rng.below(2) as usize];
    (cfg, lookahead)
}

#[test]
fn indexed_platform_bit_identical_across_policies_and_engines() {
    check("platform sched index differential across policies", 3, deep_pattern, |(cfg, la)| {
        for sched in SchedKind::ALL {
            for engine in EngineKind::ALL {
                run_platform_differential(cfg, sched, MappingPolicy::row_col_bank(), engine, *la)
                    .map_err(|e| format!("{sched}/{engine:?}: {e}"))?;
            }
        }
        Ok(())
    })
}

#[test]
fn indexed_platform_bit_identical_across_mappings() {
    check("platform sched index differential across mappings", 2, deep_pattern, |(cfg, la)| {
        for mapping in MappingPolicy::builtins() {
            for engine in EngineKind::ALL {
                run_platform_differential(cfg, SchedKind::FrFcfs, mapping, engine, *la)
                    .map_err(|e| format!("{mapping}/{engine:?}: {e}"))?;
            }
        }
        Ok(())
    })
}

// ------------------------------------------------------------------------
// Wake conservatism: idle_until never sleeps past the first oracle issue
// ------------------------------------------------------------------------

#[test]
fn fast_path_sleep_never_skips_an_oracle_issue() {
    // Whenever the indexed controller decides to sleep (tick fast path),
    // force a scan-oracle clone to run a full evaluation at cycles
    // inside the skipped window: it must issue nothing there. Each probe
    // clones the post-tick state afresh, because in real execution the
    // skipped cycles run no scheduler logic at all (not even the mode
    // automaton).
    check(
        "idle_until wake conservatism vs scan oracle",
        4,
        |rng| {
            let sched = SchedKind::ALL[rng.below(5) as usize];
            let idle = [0u32, 64][rng.below(2) as usize];
            (rng.next_u64(), sched, idle)
        },
        |&(seed, sched, idle)| {
            let params =
                ControllerParams { sched, idle_precharge_cycles: idle, ..Default::default() };
            let geo = DramGeometry::profpga_board();
            let timing = TimingParams::for_bin(SpeedBin::Ddr4_1600);
            let mut c = MemController::new(params, timing, geo);
            let mut rng = SplitMix64::new(seed);
            let mut gen = StreamGen::new(AddrStream::Mixed, &geo, seed);
            let mut id = 0u64;
            let mut done: Vec<Completion> = Vec::new();
            let mut probes = 0u32;
            let mut windows = 0u32;
            for now in 0..30_000u64 {
                // low push rate: long idle gaps are where the fast path sleeps
                if rng.percent(8) {
                    let addr = gen.next(&mut rng);
                    let req = MemRequest {
                        txn_id: id,
                        is_write: rng.percent(40),
                        addr: geo.decode(addr),
                        burst_addr: addr,
                        beats: 2,
                        arrival: now,
                        last_of_txn: true,
                    };
                    if c.try_push(req).is_ok() {
                        id += 1;
                    }
                }
                c.tick(now);
                c.pop_completions(now, &mut done);
                if probes >= 2_500 {
                    continue;
                }
                let Some(until) = c.debug_sleep_until() else { continue };
                if until <= now + 1 {
                    continue;
                }
                windows += 1;
                // probe the front of the skipped window plus its last cycle
                let first = now + 1;
                let mut ts: Vec<Cycle> = (first..until.min(first + 6)).collect();
                if until - 1 >= first + 6 {
                    ts.push(until - 1);
                }
                for t in ts {
                    let mut probe = c.clone();
                    probe.debug_set_oracle(true);
                    if let Some(cmd) = probe.debug_force_eval(t) {
                        return Err(format!(
                            "cycle {now}: fast path sleeps to {until}, \
                             but the oracle issues {cmd:?} at skipped cycle {t}"
                        ));
                    }
                    probes += 1;
                }
            }
            if windows == 0 {
                return Err("run produced no sleep windows to probe".into());
            }
            Ok(())
        },
    )
}
