//! Cross-module integration tests: the full platform (TG → controller →
//! device) under every run-time configuration axis of the paper's
//! Table I, plus the paper's qualitative claims as assertions.
//!
//! These run without the XLA artifacts (pure-Rust data path); the
//! artifact-dependent paths live in `runtime_artifacts.rs`.

use ddr4bench::config::{
    AddrMode, BurstKind, BurstSpec, DesignConfig, OpMix, PatternConfig, Signaling, SpeedBin,
};
use ddr4bench::platform::Platform;
use ddr4bench::report::campaign;

fn platform_1600() -> Platform {
    Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600))
}

fn run(platform: &mut Platform, cfg: &PatternConfig) -> ddr4bench::stats::BatchStats {
    platform.run_batch(0, cfg).expect("batch")
}

// ------------------------------------------------ full configuration grid

#[test]
fn every_pattern_axis_combination_completes() {
    // The whole Table I run-time space (coarse grid) plus the extended
    // pattern engine: op × addressing × burst type × length class ×
    // signaling. Every combination must complete with conserved counters.
    let mut platform = platform_1600();
    let addr_modes = [
        AddrMode::Sequential,
        AddrMode::Random { seed: 3 },
        AddrMode::Strided { stride: 64 << 10 },
        AddrMode::BankConflict { seed: 3 },
        AddrMode::PointerChase { seed: 3, working_set: 1 << 20 },
        AddrMode::Phased(vec![(AddrMode::Sequential, 16), (AddrMode::Random { seed: 3 }, 16)]),
    ];
    for op in [OpMix::ReadOnly, OpMix::WriteOnly, OpMix::Mixed { read_pct: 50 }] {
        for addr in &addr_modes {
            for kind in [BurstKind::Fixed, BurstKind::Incr, BurstKind::Wrap] {
                for len in [1u32, 4, 16] {
                    if kind == BurstKind::Wrap && len < 2 {
                        continue;
                    }
                    for sig in
                        [Signaling::NonBlocking, Signaling::Blocking, Signaling::Aggressive]
                    {
                        let mut cfg = PatternConfig::seq_read_burst(len, 64);
                        cfg.op = op;
                        cfg.addr = addr.clone();
                        cfg.burst = BurstSpec { len, kind };
                        cfg.signaling = sig;
                        let stats = run(&mut platform, &cfg);
                        assert_eq!(
                            stats.counters.rd_txns + stats.counters.wr_txns,
                            64,
                            "{op:?}/{addr:?}/{kind:?}/{len}/{sig:?}"
                        );
                    }
                }
            }
        }
    }
}

// ------------------------------------------------- pattern-engine ordering

#[test]
fn row_miss_stride_slower_than_sequential() {
    // A full-row stride turns every transaction into a row miss while the
    // transaction stream stays perfectly predictable: it must land well
    // below the sequential stream and in the neighbourhood of random.
    let mut p = platform_1600();
    let seq = run(&mut p, &PatternConfig::seq_read_burst(1, 1024)).read_throughput_gbs();
    let strided =
        run(&mut p, &PatternConfig::strided_read(64 << 10, 1, 1024)).read_throughput_gbs();
    assert!(
        strided < seq / 2.0,
        "row-miss stride {strided:.2} GB/s should be far below sequential {seq:.2} GB/s"
    );
}

#[test]
fn small_stride_behaves_like_sequential() {
    // A one-slot stride IS the sequential walk.
    let mut p = platform_1600();
    let seq = run(&mut p, &PatternConfig::seq_read_burst(4, 512)).read_throughput_gbs();
    let strided = run(&mut p, &PatternConfig::strided_read(128, 4, 512)).read_throughput_gbs();
    assert!(
        (strided - seq).abs() / seq < 0.05,
        "128 B stride {strided:.2} ~= sequential {seq:.2}"
    );
}

#[test]
fn bank_conflict_no_faster_than_random() {
    // Same-bank row misses can't exploit bank parallelism: the adversarial
    // stream must not beat uniform random (which spreads over all banks).
    let mut p = platform_1600();
    let rnd = run(&mut p, &PatternConfig::rnd_read_burst(1, 1024, 9)).read_throughput_gbs();
    let bank = run(&mut p, &PatternConfig::bank_conflict_read(1, 1024, 9)).read_throughput_gbs();
    assert!(
        bank <= rnd * 1.05,
        "bank-conflict {bank:.2} GB/s must not beat random {rnd:.2} GB/s"
    );
}

#[test]
fn pointer_chase_never_beats_random_and_pays_latency() {
    // Dependent single-beat accesses (blocking signaling) pay at least the
    // full row-miss cadence per transaction: the chase can never beat
    // independent random traffic and sits far below the sequential stream.
    let mut p = platform_1600();
    let seq = run(&mut p, &PatternConfig::seq_read_burst(1, 512)).read_throughput_gbs();
    let rnd = run(&mut p, &PatternConfig::rnd_read_burst(1, 512, 5)).read_throughput_gbs();
    let chase =
        run(&mut p, &PatternConfig::pointer_chase_read(4 << 20, 512, 5)).read_throughput_gbs();
    assert!(
        chase <= rnd * 1.001,
        "dependent chase {chase:.2} GB/s must not beat independent random {rnd:.2} GB/s"
    );
    assert!(chase < seq / 2.0, "chase {chase:.2} far below sequential {seq:.2}");
    assert!(chase > 0.0, "chase still makes progress");
}

#[test]
fn phased_pattern_sits_between_its_phases() {
    let mut p = platform_1600();
    let seq = run(&mut p, &PatternConfig::seq_read_burst(1, 1024)).read_throughput_gbs();
    let rnd = run(&mut p, &PatternConfig::rnd_read_burst(1, 1024, 7)).read_throughput_gbs();
    let mut cfg = PatternConfig::seq_read_burst(1, 1024);
    cfg.addr = AddrMode::Phased(vec![
        (AddrMode::Sequential, 256),
        (AddrMode::Random { seed: 7 }, 256),
    ]);
    let phased = run(&mut p, &cfg).read_throughput_gbs();
    assert!(
        phased < seq && phased > rnd * 0.9,
        "phased {phased:.2} between rnd {rnd:.2} and seq {seq:.2}"
    );
}

#[test]
fn all_speed_bins_run_and_order_correctly() {
    // Faster bins must never be slower on sequential streams.
    let mut last = 0.0;
    for speed in SpeedBin::ALL {
        let mut p = Platform::new(DesignConfig::single_channel(speed));
        let s = p.run_batch(0, &PatternConfig::seq_read_burst(32, 1024)).unwrap();
        let gbs = s.read_throughput_gbs();
        assert!(gbs > last, "{speed}: {gbs:.2} <= previous {last:.2}");
        last = gbs;
    }
}

// ------------------------------------------------------ paper-shape claims

#[test]
fn paper_shape_table4_holds() {
    // The headline shapes of Table IV at reduced scale (exact values in
    // EXPERIMENTS.md): seq ≫ rnd for singles; short bursts ≈2x singles
    // (seq) and ≈3-4x (rnd); random recovers by medium bursts; reads ≥
    // writes sequentially.
    let d = campaign::table4_data(0.05);
    let (rd, wr) = (d.gbs[0], d.gbs[1]);
    // seq singles ~3, rnd singles ~0.5
    assert!(rd[0][0] / rd[1][0] > 4.0, "read seq/rnd singles {:.2}/{:.2}", rd[0][0], rd[1][0]);
    assert!(wr[0][0] / wr[1][0] > 4.0, "write seq/rnd singles");
    // short burst speedup
    let sb = rd[0][1] / rd[0][0];
    assert!((1.6..=2.6).contains(&sb), "seq SB speedup {sb:.2} (paper ~2x)");
    let sb_rnd = rd[1][1] / rd[1][0];
    assert!(sb_rnd > 2.5, "rnd SB speedup {sb_rnd:.2} (paper ~4x)");
    // random recovery at medium bursts
    assert!(rd[1][2] > 0.9 * rd[0][2], "rnd MB recovers to ~seq");
    // sequential reads >= writes
    for li in 0..4 {
        assert!(rd[0][li] >= wr[0][li] * 0.98, "read >= write at len idx {li}");
    }
}

#[test]
fn refresh_disabled_vs_enabled_ablation() {
    // Ablation: the refresh machinery costs visible throughput on long
    // batches (the §II-C "refresh-related performance degradation").
    let mut with = platform_1600();
    let s = with.run_batch(0, &PatternConfig::rnd_read_burst(1, 3000, 5)).unwrap();
    assert!(s.counters.refresh_stall_dram_cycles > 0, "refresh must fire on long batches");
    assert!(s.refresh_degradation() > 0.0);
}

#[test]
fn blocking_signaling_degrades_most_on_reads() {
    let mut p = platform_1600();
    let mut blk = PatternConfig::seq_read_burst(4, 512);
    blk.signaling = Signaling::Blocking;
    let b = run(&mut p, &blk).read_throughput_gbs();
    let nb = run(&mut p, &PatternConfig::seq_read_burst(4, 512)).read_throughput_gbs();
    assert!(b < nb, "blocking {b:.2} must be slower than non-blocking {nb:.2}");
}

#[test]
fn fixed_bursts_hit_single_dram_burst() {
    // FIXED bursts replay one DRAM burst: DRAM-side work stays constant
    // while AXI moves len× the data — device read count shows it.
    let mut p = platform_1600();
    let mut cfg = PatternConfig::seq_read_burst(8, 256);
    cfg.burst.kind = BurstKind::Fixed;
    let s = run(&mut p, &cfg);
    assert_eq!(s.counters.rd_bytes, 256 * 8 * 32, "AXI bytes count replayed beats");
}

#[test]
fn wrap_equals_incr_throughput_when_aligned() {
    let mut p = platform_1600();
    let mut wrap = PatternConfig::seq_read_burst(8, 512);
    wrap.burst.kind = BurstKind::Wrap;
    let w = run(&mut p, &wrap).read_throughput_gbs();
    let i = run(&mut p, &PatternConfig::seq_read_burst(8, 512)).read_throughput_gbs();
    assert!((w - i).abs() / i < 0.05, "aligned WRAP {w:.2} ≈ INCR {i:.2}");
}

// ------------------------------------------------- multi-batch statefulness

#[test]
fn memory_contents_persist_across_batches() {
    let mut p = platform_1600();
    let region = 256 * 64;
    let mut w = PatternConfig::seq_write_burst(2, 256);
    w.verify = true;
    w.region_bytes = region;
    run(&mut p, &w);
    // three read passes, all clean
    let mut r = PatternConfig::seq_read_burst(2, 256);
    r.verify = true;
    r.region_bytes = region;
    for pass in 0..3 {
        let s = run(&mut p, &r);
        assert_eq!(s.counters.mismatches, 0, "pass {pass}");
    }
}

#[test]
fn unwritten_memory_not_counted_as_mismatch() {
    let mut p = platform_1600();
    let mut r = PatternConfig::rnd_read_burst(4, 128, 9);
    r.verify = true;
    let s = run(&mut p, &r);
    assert_eq!(s.counters.mismatches, 0, "reads of never-written bursts are not checkable");
}

#[test]
fn refresh_phase_continues_across_batches() {
    // The device's tREFI cadence is platform-lifetime, not per-batch:
    // many short batches must still accumulate refresh stalls.
    let mut p = platform_1600();
    let mut total = 0;
    for _ in 0..40 {
        let s = run(&mut p, &PatternConfig::seq_read_burst(8, 128));
        total += s.counters.refresh_stall_dram_cycles;
    }
    assert!(total > 0, "refresh must fire across batch boundaries");
}

// ------------------------------------------------------------ multi-channel

#[test]
fn channels_are_independent() {
    let mut p = Platform::new(DesignConfig::with_channels(2, SpeedBin::Ddr4_1600));
    // write+verify on channel 0 only; channel 1 unwritten
    let region = 128 * 64;
    let mut w = PatternConfig::seq_write_burst(2, 128);
    w.verify = true;
    w.region_bytes = region;
    p.run_batch(0, &w).unwrap();
    let mut r = PatternConfig::seq_read_burst(2, 128);
    r.verify = true;
    r.region_bytes = region;
    // channel 0 verifies written data; channel 1 has nothing checkable
    assert_eq!(p.run_batch(0, &r).unwrap().counters.mismatches, 0);
    assert_eq!(p.run_batch(1, &r).unwrap().counters.mismatches, 0);
    // fault on channel 0 must not affect channel 1
    assert!(p.corrupt(0, 0, 0, 1));
    assert_eq!(p.run_batch(0, &r).unwrap().counters.mismatches, 1);
    assert_eq!(p.run_batch(1, &r).unwrap().counters.mismatches, 0);
}

#[test]
fn aggregate_scaling_within_tolerance_all_speeds() {
    for speed in [SpeedBin::Ddr4_1600, SpeedBin::Ddr4_2400] {
        let cfg = PatternConfig::seq_read_burst(32, 512);
        let s1 = {
            let mut p = Platform::new(DesignConfig::with_channels(1, speed));
            Platform::aggregate(&p.run_batch_all(&cfg).unwrap()).read_throughput_gbs()
        };
        let s3 = {
            let mut p = Platform::new(DesignConfig::with_channels(3, speed));
            Platform::aggregate(&p.run_batch_all(&cfg).unwrap()).read_throughput_gbs()
        };
        let ratio = s3 / s1;
        assert!((2.85..=3.15).contains(&ratio), "{speed}: triple/single = {ratio:.2}");
    }
}

// ------------------------------------------------------------- trace replay

#[test]
fn trace_replay_matches_equivalent_pattern() {
    use ddr4bench::trafficgen::trace;
    // A pure-sequential-read trace must match the synthetic pattern's
    // throughput (same executive underneath).
    let records = trace::synth::streaming(1024, 32, 256 << 20, 0);
    let mut p = platform_1600();
    let traced = p.run_trace(0, &records, false).unwrap();
    let synthetic = p.run_batch(0, &PatternConfig::seq_read_burst(32, 1024)).unwrap();
    let (a, b) = (traced.read_throughput_gbs(), synthetic.read_throughput_gbs());
    assert!((a - b).abs() / b < 0.05, "trace {a:.2} vs pattern {b:.2}");
}

#[test]
fn trace_shapes_order_as_expected() {
    use ddr4bench::trafficgen::trace;
    let mut p = platform_1600();
    let stream = p
        .run_trace(0, &trace::synth::streaming(1024, 32, 64 << 20, 0), false)
        .unwrap()
        .total_throughput_gbs();
    let chase = p
        .run_trace(0, &trace::synth::pointer_chase(1024, 1 << 30, 1), false)
        .unwrap()
        .total_throughput_gbs();
    let hot = p
        .run_trace(0, &trace::synth::hot_set(1024, 4, 1 << 30, 2), false)
        .unwrap()
        .total_throughput_gbs();
    assert!(stream > hot, "streaming {stream:.2} > hot-set {hot:.2}");
    assert!(hot > chase, "hot-set {hot:.2} > pointer-chase {chase:.2}");
}

// ------------------------------------------------------------------- energy

#[test]
fn energy_stats_populated_and_ordered() {
    let mut p = platform_1600();
    let seq = run(&mut p, &PatternConfig::seq_read_burst(32, 2048));
    let rnd = run(&mut p, &PatternConfig::rnd_read_burst(1, 2048, 3));
    assert!(seq.energy.total_nj() > 0.0);
    assert!(seq.pj_per_bit().unwrap() > 0.0);
    // random traffic costs more energy per bit (row cycles + standby time)
    assert!(
        rnd.pj_per_bit().unwrap() > 2.0 * seq.pj_per_bit().unwrap(),
        "rnd {:.1} vs seq {:.1} pJ/bit",
        rnd.pj_per_bit().unwrap(),
        seq.pj_per_bit().unwrap()
    );
    // plausible DDR4 channel power range under load
    let mw = seq.avg_power_mw();
    assert!((100.0..3000.0).contains(&mw), "{mw:.0} mW");
}

// ----------------------------------------------------------------- analytic

#[test]
fn analytic_model_tracks_simulator_shape() {
    // Model vs simulator on the Table IV grid: every point within 2x and
    // mean relative error bounded (exact numbers in EXPERIMENTS.md).
    let (_, mae) = campaign::model_check(0.05);
    assert!(mae < 0.5, "model MAE vs simulator = {:.2}", mae);
}
