#!/usr/bin/env python3
"""Repo structure lints, run as a CI gate (see .github/workflows/ci.yml).

Because the Rust tree lives under rust/ (a non-standard cargo layout),
cargo does NOT autodiscover integration tests or benches: a test file
that exists on disk but is missing its [[test]] stanza in Cargo.toml is
silently never compiled or run. These lints make that class of drift --
and the analogous docs drift -- a loud CI failure:

  1. every rust/tests/*.rs is declared as a [[test]] in Cargo.toml
     (and every declared [[test]] path exists);
  2. every rust/benches/*.rs is declared as a [[bench]] likewise;
  3. every host-protocol command in hostctrl::proto::COMMANDS has a row
     in the README's protocol reference table;
  4. every protocol-audit rule ID in check::rules (RuleId::id) has a row
     in the README's "Protocol audit" rule table.

Stdlib only; exits nonzero with one line per finding.
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def fail(errors):
    for e in errors:
        print(f"lint_repo: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)


def declared_targets(cargo_text, kind):
    """Map name -> path for every [[kind]] stanza in Cargo.toml."""
    out = {}
    blocks = re.split(r"^\[", cargo_text, flags=re.M)
    for block in blocks:
        if not block.startswith(f"[{kind}]]"):
            continue
        name = re.search(r'^name\s*=\s*"([^"]+)"', block, re.M)
        path = re.search(r'^path\s*=\s*"([^"]+)"', block, re.M)
        if name and path:
            out[name.group(1)] = path.group(1)
    return out


def check_target_sync(cargo_text, kind, directory, errors):
    declared = declared_targets(cargo_text, kind)
    declared_paths = set(declared.values())
    on_disk = sorted((ROOT / directory).glob("*.rs"))
    for f in on_disk:
        rel = f.relative_to(ROOT).as_posix()
        if rel not in declared_paths:
            errors.append(
                f"{rel} exists but has no [[{kind}]] stanza in Cargo.toml "
                f"(non-standard layout: cargo will silently skip it)"
            )
    for name, path in declared.items():
        if not (ROOT / path).is_file():
            errors.append(f"[[{kind}]] {name} points at missing file {path}")


def rust_string_list(text, pattern):
    return re.findall(pattern, text)


def readme_table_cells(readme_text):
    """All first-column `code` cells of markdown table rows."""
    return set(re.findall(r"^\|\s*`([^`]+)`\s*\|", readme_text, re.M))


def main():
    errors = []
    cargo = (ROOT / "Cargo.toml").read_text()
    readme = (ROOT / "README.md").read_text()

    check_target_sync(cargo, "test", "rust/tests", errors)
    check_target_sync(cargo, "bench", "rust/benches", errors)

    # host-protocol commands: one README table row per COMMANDS entry
    proto = (ROOT / "rust/src/hostctrl/proto.rs").read_text()
    commands = rust_string_list(proto, r'name:\s*"([A-Z]+)"')
    if not commands:
        errors.append("no COMMANDS entries parsed from rust/src/hostctrl/proto.rs")
    cells = readme_table_cells(readme)
    for cmd in commands:
        if cmd not in cells:
            errors.append(f"protocol command {cmd} has no README table row (| `{cmd}` | ...)")

    # audit rule IDs: one README table row per RuleId::id() string
    rules = (ROOT / "rust/src/check/rules.rs").read_text()
    id_fn = re.search(r"pub fn id\(self\).*?\n    \}", rules, re.S)
    if not id_fn:
        errors.append("cannot locate RuleId::id() in rust/src/check/rules.rs")
        fail(errors)
    rule_ids = rust_string_list(id_fn.group(0), r'=>\s*"([^"]+)"')
    if len(rule_ids) < 20:
        errors.append(f"only {len(rule_ids)} rule IDs parsed from RuleId::id(); expected >= 20")
    for rid in rule_ids:
        if rid not in cells:
            errors.append(f"audit rule {rid} has no README table row (| `{rid}` | ...)")

    fail(errors)
    print(
        f"lint_repo: OK ({len(declared_targets(cargo, 'test'))} tests, "
        f"{len(declared_targets(cargo, 'bench'))} benches, "
        f"{len(commands)} protocol commands, {len(rule_ids)} audit rules)"
    )


if __name__ == "__main__":
    main()
