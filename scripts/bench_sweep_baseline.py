"""Generate the committed ``BENCH_sweep.json`` baseline from the analytic
bandwidth model.

The canonical generator for this file is the simulator-backed sweep
executive::

    cargo run --release -- sweep --speeds 1600,2400 --channels 1,2 \
        --patterns strided,bank,chase --jobs 4 --out sweep-out

This script exists for environments without a Rust toolchain: it walks
the same 12-job grid (the Fig. 2 data rates x {1, 2} channels x the
three adversarial patterns) through ``python/compile/model.py``'s
``bw_model`` — the jnp twin of ``rust/src/analytic`` — and emits the
same ``ddr4bench.sweep.v4`` schema with ``"source"`` marking the values
as analytic predictions rather than simulator measurements. Fields the
model cannot predict (latency and its percentiles, wall time, refresh,
energy) are null; the mapping/knob/sched axes are the defaults the
simulator grid runs under (``row_col_bank``/``mig``/``frfcfs``).

Run from the repo root: ``python3 scripts/bench_sweep_baseline.py``
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "python"))

import numpy as np  # noqa: E402

from compile import model  # noqa: E402

# (label, burst_len, row_hostile, cfg echo) — mirrors sweep::preset() in
# rust/src/platform/sweep.rs; read_frac is 1.0 (read-only presets).
PATTERNS = [
    (
        "strided",
        4.0,
        1.0,  # 64 KiB stride >= row span -> row-miss service time
        "OP=R ADDR=STRIDE STRIDE=65536 BURST=4 TYPE=INCR SIG=NB BATCH=2048",
    ),
    ("bank", 1.0, 1.0, "OP=R ADDR=BANK SEED=1 BURST=1 TYPE=INCR SIG=NB BATCH=1024"),
    (
        "chase",
        1.0,
        1.0,
        "OP=R ADDR=CHASE SEED=7 WSET=4194304 BURST=1 TYPE=INCR SIG=BLK BATCH=1024",
    ),
]
SPEEDS = [1600, 2400]
CHANNELS = [1, 2]

# BwFeatures order: rate, burst_len, random, read_frac, beat_bytes,
# addr_interval, lookahead, outstanding (ControllerParams defaults).
def feature_row(rate, blen, hostile):
    return [rate, blen, hostile, 1.0, 32.0, 2.0, 4.0, 8.0]


def main():
    rows, meta = [], []
    job_id = 0
    for rate in SPEEDS:
        for ch in CHANNELS:
            for label, blen, hostile, cfg in PATTERNS:
                rows.append(feature_row(rate, blen, hostile))
                meta.append((job_id, rate, ch, label, cfg))
                job_id += 1
    feats = np.zeros((model.BWMODEL_BLOCK, model.BWMODEL_FEATURES), np.float32)
    feats[: len(rows)] = np.asarray(rows, np.float32)
    preds = np.asarray(model.bw_model(feats))[: len(rows)]

    jobs = []
    for (jid, rate, ch, label, cfg), per_channel in zip(meta, preds):
        total = float(per_channel) * ch
        jobs.append(
            {
                "schema": "ddr4bench.sweep.v4",
                "id": jid,
                "speed": f"DDR4-{rate}",
                "data_rate_mts": rate,
                "channels": ch,
                "pattern": label,
                "mapping": "row_col_bank",
                "knobs": "mig",
                "sched": "frfcfs",
                "mix": "",
                "cfg": cfg,
                "rd_gbs": round(total, 6),
                "wr_gbs": 0.0,
                "total_gbs": round(total, 6),
                "rd_lat_ns": None,
                "wr_lat_ns": None,
                "rd_p50_ns": None,
                "rd_p95_ns": None,
                "rd_p99_ns": None,
                "wr_p50_ns": None,
                "wr_p95_ns": None,
                "wr_p99_ns": None,
                "refresh_stall_ck": None,
                "mismatches": None,
                "energy_nj": None,
                "pj_per_bit": None,
                "wall_ms": None,
                "per_channel_total_gbs": [round(float(per_channel), 6)] * ch,
            }
        )
    doc = {
        "schema": "ddr4bench.sweep.v4",
        "source": (
            "analytic-model baseline (python/compile/model.py bw_model); "
            "promote a simulator-sourced summary with "
            "scripts/promote_baseline.sh (CI uploads one as the "
            "BENCH_sweep artifact every run) to flip the CI compare gate "
            "to --strict"
        ),
        "jobs": jobs,
    }
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweep.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.normpath(out)} ({len(jobs)} jobs)")


if __name__ == "__main__":
    main()
