#!/usr/bin/env sh
# Promote a simulator-produced sweep summary to the committed repo
# baseline. The CI `rust` job regenerates `sweep-out/BENCH_sweep.json`
# with the cycle-level simulator on every push and uploads it as the
# `BENCH_sweep` artifact; committing it here replaces the analytic
# bootstrap baseline, and the CI compare step then gates run-to-run
# perf deltas with `--strict` automatically (it keys off the `source`
# field).
#
# Usage: scripts/promote_baseline.sh [path/to/BENCH_sweep.json]
set -eu
src="${1:-sweep-out/BENCH_sweep.json}"
if ! grep -q '"source": "ddr4bench sweep executive (simulator)"' "$src"; then
    echo "refusing: $src is not a simulator-sourced sweep summary" >&2
    echo "(run: cargo run --release -- sweep --speeds 1600,2400 --channels 1,2 \\" >&2
    echo "      --patterns strided,bank,chase --jobs 4 --out sweep-out)" >&2
    exit 1
fi
dst="$(dirname "$0")/../BENCH_sweep.json"
cp "$src" "$dst"
echo "promoted $src -> BENCH_sweep.json; the CI compare step now gates --strict"
