#!/usr/bin/env python3
"""Bench-server smoke test: concurrent scripted clients over real TCP.

Connects ``--clients`` simultaneous sessions to a running ``ddr4bench
serve`` instance (2+ channels), drives each through its own command
script, and requires every reply line to be ``OK ...``. One extra
streaming session turns ``STREAM ON`` before a long pooled run and
requires at least one ``STREAM ...`` heartbeat line to land before the
run's terminal reply. Exits 0 on success, 1 with a per-client failure
report otherwise — the CI gate backgrounds the server (with a short
``--stream-interval-ms`` so heartbeats are dense), runs this, then
checks a clean SIGTERM exit.

Usage: server_smoke.py [--addr 127.0.0.1:5557] [--clients 4]
"""

import argparse
import socket
import sys
import threading
import time

# Distinct per-client scripts (cycled when --clients > 4): plain read,
# seeded random write, a heterogeneous CHCFG/RUNMIX flow, mixed-op +
# RESET. Channel 1 appears, so the server needs --channels 2 or more.
SCRIPTS = [
    ["INFO", "CFG 0 OP=R ADDR=SEQ BURST=32 BATCH=512", "RUN 0", "STATS 0", "QUIT"],
    ["CFG 0 OP=W ADDR=RND SEED=7 BURST=4 BATCH=256", "RUN 0", "STATS 0", "QUIT"],
    [
        "CHCFG 0:SEQ,BURST=8,BATCH=128 1:BANK,SEED=3,BURST=1,BATCH=64",
        "RUNMIX",
        "STATS 1",
        "QUIT",
    ],
    ["CFG 1 OP=M RDPCT=75 ADDR=SEQ BURST=16 BATCH=256", "RUN 1", "STATS 1", "RESET 1", "QUIT"],
]


def wait_ready(host, port, timeout=30.0):
    """Retry-connect until the server accepts (it may still be building)."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            with socket.create_connection((host, port), timeout=5) as probe:
                probe.sendall(b"QUIT\n")
                probe.makefile("r").readline()
            return
        except OSError as e:
            if time.monotonic() >= deadline:
                sys.exit(f"server at {host}:{port} never became ready: {e}")
            time.sleep(0.2)


def run_client(idx, host, port, script, failures):
    try:
        with socket.create_connection((host, port), timeout=60) as conn:
            conn.settimeout(60)
            reader = conn.makefile("r")
            conn.sendall(("".join(line + "\n" for line in script)).encode())
            for line_no, sent in enumerate(script):
                reply = reader.readline().rstrip("\n")
                if not reply.startswith("OK"):
                    failures.append(f"client {idx}: `{sent}` -> `{reply}`")
                    return
    except OSError as e:
        failures.append(f"client {idx}: connection error: {e}")


def run_stream_client(host, port, failures):
    """STREAM ON during a pooled run: at least one heartbeat line must
    arrive over TCP before the run's terminal ``OK RUN`` reply (the
    replies themselves must all be OK too)."""
    script = [
        "STREAM ON",
        "CFG 0 OP=R ADDR=CHASE WSET=16m BURST=1 BATCH=100000 TELEM=256",
        "RUN 0",
        "QUIT",
    ]
    try:
        with socket.create_connection((host, port), timeout=120) as conn:
            conn.settimeout(120)
            reader = conn.makefile("r")
            conn.sendall(("".join(line + "\n" for line in script)).encode())
            heartbeats = 0
            replies = []
            while len(replies) < len(script):
                line = reader.readline().rstrip("\n")
                if not line:
                    failures.append("stream client: connection closed early")
                    return
                if line.startswith("STREAM "):
                    heartbeats += 1
                else:
                    replies.append(line)
            bad = [
                f"stream client: `{sent}` -> `{reply}`"
                for sent, reply in zip(script, replies)
                if not reply.startswith("OK")
            ]
            if bad:
                failures.extend(bad)
                return
            if heartbeats == 0:
                failures.append("stream client: no STREAM heartbeat before the run completed")
                return
            print(f"server smoke: stream client saw {heartbeats} heartbeat(s) mid-run")
    except OSError as e:
        failures.append(f"stream client: connection error: {e}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--addr", default="127.0.0.1:5557", help="server address (host:port)")
    ap.add_argument("--clients", type=int, default=4, help="concurrent sessions to drive")
    args = ap.parse_args()
    host, port = args.addr.rsplit(":", 1)
    port = int(port)

    wait_ready(host, port)

    failures = []
    threads = [
        threading.Thread(
            target=run_client,
            args=(i, host, port, SCRIPTS[i % len(SCRIPTS)], failures),
        )
        for i in range(args.clients)
    ]
    threads.append(threading.Thread(target=run_stream_client, args=(host, port, failures)))
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    if failures:
        for f in failures:
            print(f"FAIL {f}", file=sys.stderr)
        sys.exit(1)
    print(
        f"server smoke: {args.clients} concurrent session(s) + 1 streaming session, "
        "all replies OK"
    )


if __name__ == "__main__":
    main()
