//! Offline shim for the subset of [`anyhow`](https://docs.rs/anyhow) that
//! `ddr4bench` uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//!
//! Error values carry a rendered message only (no backtraces, no
//! downcasting). Like the real crate, [`Error`] deliberately does *not*
//! implement `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion that makes `?` work.

use std::fmt;

/// A rendered error message, convertible from any `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a pre-rendered message.
    pub fn from_msg(msg: String) -> Self {
        Self { msg }
    }

    /// Build an error from anything displayable.
    pub fn from_display(e: &dyn fmt::Display) -> Self {
        Self { msg: e.to_string() }
    }

    /// Prepend context, anyhow-style (`context: original`).
    pub fn wrap(self, context: impl fmt::Display) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(, $($arg:tt)*)?) => {
        $crate::Error::from_msg(format!($msg $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::from_display(&$err)
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error/`None` case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message to the error/`None` case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from_msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::from_msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::from_msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "boom");
    }

    #[test]
    fn macros_format_and_wrap() {
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
        let s = String::from("plain");
        let e2 = anyhow!(s);
        assert_eq!(format!("{e2:?}"), "plain");
        fn bails() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading").unwrap_err();
        assert_eq!(e.to_string(), "loading: boom");
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3u32).context("ok").unwrap(), 3);
    }
}
