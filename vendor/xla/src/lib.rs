//! Inert stand-in for the PJRT/XLA bindings (`xla` crate) used by
//! `rust/src/runtime`. It mirrors the API surface that module calls so the
//! crate compiles and runs everywhere without the native XLA closure;
//! every entry point that would touch PJRT returns [`XlaError`] instead.
//!
//! `XlaRuntime::load` therefore always fails in this configuration, which
//! the rest of the codebase already treats as "no runtime attached" (the
//! pure-Rust data path). Point the `xla` path dependency at the real
//! bindings to light the AOT-artifact path back up; `rust/src/runtime`
//! compiles unchanged against either.

use std::fmt;

const UNAVAILABLE: &str =
    "XLA/PJRT support is not compiled in (offline stub; see vendor/README.md)";

/// Error type of the stub: every operation fails with this.
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn unavailable() -> Self {
        Self { msg: UNAVAILABLE.to_string() }
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

/// Stub result alias matching the real crate's fallible signatures.
pub type Result<T> = std::result::Result<T, XlaError>;

/// Host-side literal (tensor) handle.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice (stub: shape is not retained).
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(XlaError::unavailable())
    }

    /// Unwrap a single-element tuple literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(XlaError::unavailable())
    }

    /// Copy the literal's elements out as a vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable())
    }
}

/// Device-side buffer returned by executions.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable())
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable())
    }

    /// PJRT platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable())
    }
}

/// Compiled, device-loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given input literals.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaError::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_and_uniformly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let err = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(err.contains("not compiled in"), "{err}");
    }
}
