"""AOT compiler: lower the L2/L1 computations to HLO-text artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file``, compiles on the PJRT CPU
client, and executes — Python is never on the benchmark path.

HLO *text* (not serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids, so text round-trips cleanly.

Artifacts (shapes fixed here; Rust chunks/pads — ``runtime/mod.rs``):

========================  =========================================
``datagen.hlo.txt``       u32[4096] seeds -> (u32[4096,16],)
``verify.hlo.txt``        u32[4096], u32[4096,16] -> (u32[1],)
``bwmodel.hlo.txt``       f32[64,8] features -> (f32[64],)
========================  =========================================

Usage: ``python -m compile.aot --out ../artifacts`` (any target dir).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered):
    """StableHLO module → XlaComputation → HLO text (see module docs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_datagen():
    """Lower the payload-generation artifact."""
    seeds = jax.ShapeDtypeStruct((model.DATAGEN_BLOCK,), jnp.uint32)
    return to_hlo_text(jax.jit(lambda s: (model.datagen_block(s),)).lower(seeds))


def lower_verify():
    """Lower the read-back-verification artifact."""
    seeds = jax.ShapeDtypeStruct((model.DATAGEN_BLOCK,), jnp.uint32)
    data = jax.ShapeDtypeStruct((model.DATAGEN_BLOCK, 16), jnp.uint32)
    return to_hlo_text(jax.jit(lambda s, d: (model.verify_block(s, d),)).lower(seeds, data))


def lower_bwmodel():
    """Lower the analytic bandwidth-model artifact."""
    feats = jax.ShapeDtypeStruct((model.BWMODEL_BLOCK, model.BWMODEL_FEATURES), jnp.float32)
    return to_hlo_text(jax.jit(lambda f: (model.bw_model(f),)).lower(feats))


ARTIFACTS = {
    "datagen.hlo.txt": lower_datagen,
    "verify.hlo.txt": lower_verify,
    "bwmodel.hlo.txt": lower_bwmodel,
}


def build(out_dir):
    """Lower every artifact into ``out_dir``; returns written paths."""
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for name, lower in ARTIFACTS.items():
        text = lower()
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        print(f"wrote {len(text):>9} chars to {path}")
    return written


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact output directory")
    args = parser.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
