"""L1 Pallas kernels: the traffic generator's data path.

Two kernels, both tiled over blocks of seeds (one seed = one 64-byte DRAM
burst = 16 uint32 words):

- :func:`expand` — PRBS payload generation: each grid program expands a
  ``(BLOCK,)`` tile of seeds into a ``(BLOCK, 16)`` tile of words by 16
  unrolled xorshift32 steps. This is the hardware-adapted form of the RTL
  TG's per-lane LFSRs: the BlockSpec HBM↔VMEM schedule plays the role of
  the RTL's per-beat streaming, and the 16-step unroll is the parallel
  lane bank (DESIGN.md §8).
- :func:`verify_counts` — read-back checking: expands the seed tile,
  compares against the observed data tile, and reduces a per-program
  mismatch count.

Both MUST be lowered with ``interpret=True``: the CPU PJRT client cannot
execute Mosaic custom-calls (real-TPU lowering); interpret mode lowers to
plain HLO that runs anywhere, and numerics are identical.

VMEM budget per program (BLOCK=512): seeds 2 KiB in + words 32 KiB out +
one 2 KiB live lane register ≈ 36 KiB ≪ the ~16 MiB VMEM of a TPU core,
leaving headroom to scale BLOCK to 64Ki rows if this were compiled for
real hardware (DESIGN.md §8 records the estimate).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows (seeds) per grid program.
BLOCK = 512

# Words per burst, re-exported for the model layer.
WORDS_PER_BURST = ref.WORDS_PER_BURST


def _expand_kernel(seeds_ref, out_ref):
    """Grid program: expand one (BLOCK,) seed tile to (BLOCK, 16) words."""
    s = seeds_ref[...]
    # Zero-seed remap to 0x9E3779B9, built from in-range python literals
    # (pallas kernels may not capture array constants, and a bare
    # 0x9E3779B9 literal overflows the weak int32 type).
    zero = (s == 0).astype(jnp.uint32)
    s = s + zero * 0x79B9 + ((zero * 0x9E37) << 16)
    # 16 unrolled xorshift32 steps — the RTL's parallel LFSR lane bank.
    for i in range(WORDS_PER_BURST):
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        out_ref[:, i] = s


def expand(seeds):
    """Expand ``seeds`` (uint32 [n], n a multiple of BLOCK) to [n, 16]."""
    n = seeds.shape[0]
    assert n % BLOCK == 0, f"n={n} must be a multiple of BLOCK={BLOCK}"
    return pl.pallas_call(
        _expand_kernel,
        grid=(n // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK, WORDS_PER_BURST), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, WORDS_PER_BURST), jnp.uint32),
        interpret=True,
    )(seeds.astype(jnp.uint32))


def _verify_kernel(seeds_ref, data_ref, out_ref):
    """Grid program: per-tile mismatch count between expansion and data."""
    s = seeds_ref[...]
    zero = (s == 0).astype(jnp.uint32)
    s = s + zero * 0x79B9 + ((zero * 0x9E37) << 16)
    mism = None
    for i in range(WORDS_PER_BURST):
        s = s ^ (s << 13)
        s = s ^ (s >> 17)
        s = s ^ (s << 5)
        step = jnp.sum(s != data_ref[:, i], dtype=jnp.uint32)
        mism = step if mism is None else mism + step
    out_ref[0] = mism


def verify_counts(seeds, data):
    """Per-program mismatch counts, uint32 [n / BLOCK].

    ``data`` is uint32 [n, 16]; sum the result for the total count (the
    model layer does that so the whole reduction stays in one HLO).
    """
    n = seeds.shape[0]
    assert n % BLOCK == 0, f"n={n} must be a multiple of BLOCK={BLOCK}"
    assert data.shape == (n, WORDS_PER_BURST)
    return pl.pallas_call(
        _verify_kernel,
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK, WORDS_PER_BURST), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // BLOCK,), jnp.uint32),
        interpret=True,
    )(seeds.astype(jnp.uint32), data.astype(jnp.uint32))
