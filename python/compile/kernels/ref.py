"""Pure-jnp reference oracle for the PRBS data-path kernels.

This module is the *specification*: the Pallas kernels in ``prbs.py`` must
match it bit-for-bit (pytest + hypothesis enforce that), and the Rust
mirror (``rust/src/trafficgen/payload.rs``) pins the same constants, so
all three implementations of the traffic generator's data path agree.

The data path (paper §II-B, the differentiator vs. Shuhai's all-zeros
writes):

1. every 64-byte DRAM burst gets a 32-bit seed derived from its byte
   address and the pattern seed (:func:`burst_seed_ref`);
2. the seed expands to the burst's 16 data words by 16 xorshift32 steps
   (:func:`expand_ref`) — non-zero by construction;
3. verification recomputes the expansion and counts mismatching words
   (:func:`verify_ref`).
"""

import jax.numpy as jnp

# Words per 64-byte DRAM burst (16 x u32).
WORDS_PER_BURST = 16

# Non-zero remap constant for zero seeds (2^32 / golden ratio).
_SEED_REMAP = jnp.uint32(0x9E3779B9)


def xorshift32_step(x):
    """One xorshift32 step (Marsaglia 13/17/5 triple) on uint32 arrays."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x << 13)
    x = x ^ (x >> 17)
    x = x ^ (x << 5)
    return x


def expand_ref(seeds):
    """Expand ``seeds`` (uint32 [n]) to payload words (uint32 [n, 16]).

    Zero seeds are remapped to a fixed non-zero constant first, matching
    the Rust ``Xorshift32::new`` remap, so the expansion never yields an
    all-zero stream.
    """
    s = jnp.asarray(seeds, jnp.uint32)
    s = jnp.where(s == 0, _SEED_REMAP, s)
    words = []
    for _ in range(WORDS_PER_BURST):
        s = xorshift32_step(s)
        words.append(s)
    return jnp.stack(words, axis=-1)


def verify_ref(seeds, data):
    """Mismatch count between ``expand_ref(seeds)`` and ``data`` [n, 16]."""
    expected = expand_ref(seeds)
    return jnp.sum(expected != jnp.asarray(data, jnp.uint32), dtype=jnp.uint32)


def burst_seed_ref(burst_indices, pattern_seed):
    """Per-burst seed hash (Murmur3-finalizer mix), uint32 [n].

    ``burst_indices`` are byte addresses divided by 64 (the Rust side does
    the shift before handing seeds to XLA, keeping everything in u32 here
    without enabling x64). Mirrors ``payload::burst_seed`` in Rust — the
    pinned-value tests in ``python/tests/test_kernels.py`` keep the two in
    lockstep.
    """
    idx = jnp.asarray(burst_indices, jnp.uint32)
    ps = jnp.uint32(pattern_seed)
    rot = (ps << 16) | (ps >> 16)
    h = idx ^ rot
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return jnp.where(h == 0, _SEED_REMAP, h)
