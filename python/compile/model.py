"""L2 JAX model layer: the computations that get AOT-lowered to HLO.

Three entry points, each lowered by ``aot.py`` into one artifact the Rust
runtime executes via PJRT (shapes are fixed at lowering time; the Rust
wrappers chunk and pad — see ``rust/src/runtime/mod.rs``):

- :func:`datagen_block` — PRBS payload generation for one block of burst
  seeds (wraps the L1 Pallas kernel :func:`compile.kernels.prbs.expand`).
- :func:`verify_block` — read-back verification: total mismatch count
  between the expansion of the seeds and the observed data (wraps the L1
  Pallas kernel, reduces its per-program counts in the same HLO).
- :func:`bw_model` — the closed-form DDR4 bandwidth model, vectorized
  over configuration rows (pure jnp; mirrors
  ``rust/src/analytic/predict_gbs`` — the cross-check tests in
  ``rust/tests/runtime_artifacts.rs`` and ``python/tests/test_model.py``
  keep the two in lockstep).
"""

import jax.numpy as jnp

from .kernels import prbs

# Block sizes baked into the artifacts (mirrored by rust/src/runtime).
DATAGEN_BLOCK = 4096
BWMODEL_BLOCK = 64
BWMODEL_FEATURES = 8


def datagen_block(seeds):
    """Expand a block of burst seeds to payload words.

    Args:
      seeds: uint32 [DATAGEN_BLOCK].

    Returns:
      uint32 [DATAGEN_BLOCK, 16].
    """
    return prbs.expand(seeds)


def verify_block(seeds, data):
    """Total mismatch count between ``expand(seeds)`` and ``data``.

    Args:
      seeds: uint32 [DATAGEN_BLOCK].
      data: uint32 [DATAGEN_BLOCK, 16].

    Returns:
      uint32 [1] (kept rank-1 so the Rust side reads it with ``to_vec``).
    """
    counts = prbs.verify_counts(seeds, data)
    return jnp.sum(counts, dtype=jnp.uint32).reshape((1,))


def _ceil_ck(ns, tck_ns, min_ck):
    """JEDEC ns→nCK conversion: ceil with an nCK floor.

    The epsilon guards exact-boundary quotients (e.g. 7.5 ns / 1.25 ns):
    the xla_extension 0.5.1 CPU backend lowers f32 division through an
    approximate reciprocal, which can land 6.0 at 6.0000001 and push the
    ceil to 7 — off-by-one versus the Rust f64 mirror.
    """
    return jnp.maximum(jnp.ceil(ns / tck_ns - 1e-4), float(min_ck))


def _timing(rate_mts):
    """Speed-bin timing table, vectorized over the data-rate column.

    Mirrors ``TimingParams::for_bin`` for the four bins of the paper.
    """
    tck = 2000.0 / rate_mts
    # CL/CWL per bin (nCK by definition).
    cl = jnp.select(
        [rate_mts <= 1700.0, rate_mts <= 2000.0, rate_mts <= 2250.0],
        [11.0, 13.0, 15.0],
        16.0,
    )
    cwl = jnp.select(
        [rate_mts <= 1700.0, rate_mts <= 2000.0, rate_mts <= 2250.0],
        [9.0, 10.0, 11.0],
        12.0,
    )
    trcd = cl
    trp = cl
    trtp = _ceil_ck(7.5, tck, 4)
    twr = _ceil_ck(15.0, tck, 0)
    twtr_l = _ceil_ck(7.5, tck, 4)
    trfc = _ceil_ck(260.0, tck, 0)
    trefi = _ceil_ck(7800.0, tck, 0)
    return dict(
        tck=tck, cl=cl, cwl=cwl, trcd=trcd, trp=trp, trtp=trtp, twr=twr,
        twtr_l=twtr_l, trfc=trfc, trefi=trefi, burst=4.0,
    )


def _direction_gbs(f, t, is_read):
    """One direction's throughput in GB/s (mirrors analytic::direction_gbs).

    Random accesses pay the page-miss pipeline flush once per transaction
    (PRE + ACT + CAS + data + recovery), partially hidden behind the
    transaction's own CAS stream — long bursts hide it entirely.
    """
    rate, blen, random, _, beat, interval, lookahead, outstanding = f
    del rate, lookahead, outstanding  # folded into the flush model
    axi_ns = t["tck"] * 4.0
    txn_bytes = blen * beat
    dbpt = jnp.maximum(txn_bytes / 64.0, 1.0)

    fabric = beat / axi_ns
    addr = txn_bytes / (interval * axi_ns)
    service_ck = dbpt * t["burst"]

    flush = t["trp"] + t["trcd"] + jnp.where(
        is_read,
        t["cl"] + t["burst"] + t["trp"],
        t["cwl"] + t["burst"] + t["twr"] + t["twtr_l"],
    )
    hidden = (dbpt - 1.0) * 4.0  # tCCD_S per extra burst
    service_rnd = service_ck + jnp.maximum(flush - hidden, 0.0)

    dram_seq = txn_bytes / (service_ck * t["tck"])
    dram_rnd = txn_bytes / (service_rnd * t["tck"])
    dram = jnp.where(random > 0.5, dram_rnd, dram_seq)
    return jnp.minimum(jnp.minimum(fabric, addr), dram)


def bw_model(feats):
    """Predicted throughput (GB/s, f32 [BWMODEL_BLOCK]) per feature row.

    Feature columns (``analytic::BwFeatures::to_row`` order):
    ``[data_rate_mts, burst_len, random, read_frac, beat_bytes,
    addr_interval, lookahead, outstanding]``. The operation mix is derived
    from ``read_frac``: 1.0 = read-only, 0.0 = write-only, else mixed.
    """
    feats = jnp.asarray(feats, jnp.float32)
    cols = [feats[:, i] for i in range(BWMODEL_FEATURES)]
    rate, _, _, read_frac = cols[0], cols[1], cols[2], cols[3]
    t = _timing(rate)

    rd = _direction_gbs(cols, t, jnp.asarray(True))
    wr = _direction_gbs(cols, t, jnp.asarray(False))

    dram_bus = 64.0 / (t["burst"] * t["tck"])
    mixed = jnp.minimum(rd * jnp.maximum(read_frac, 0.01) + wr * jnp.maximum(1.0 - read_frac, 0.01),
                        dram_bus * 0.85)
    gbs = jnp.where(read_frac >= 0.999, rd, jnp.where(read_frac <= 0.001, wr, mixed))
    refresh_derate = 1.0 - t["trfc"] / t["trefi"]
    return (gbs * refresh_derate).astype(jnp.float32)
