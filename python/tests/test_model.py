"""L2 model-layer tests: block wrappers and the analytic bandwidth model.

The bandwidth model's *shape* assertions mirror the paper's §III-C
analysis (sequential saturates, random recovers with burst length, higher
data rates help sequential more) — the same properties the Rust simulator
reproduces, so model, simulator and paper stay mutually consistent.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def feats(rate=1600, blen=32, random=0.0, read_frac=1.0,
          beat=32, interval=2, lookahead=4, outstanding=8):
    row = np.zeros((model.BWMODEL_BLOCK, model.BWMODEL_FEATURES), np.float32)
    row[0] = [rate, blen, random, read_frac, beat, interval, lookahead, outstanding]
    return row


def predict(**kw):
    return float(np.asarray(model.bw_model(feats(**kw)))[0])


# ------------------------------------------------------------- datagen/verify

def test_datagen_block_matches_ref():
    seeds = np.arange(model.DATAGEN_BLOCK, dtype=np.uint32)
    out = np.asarray(model.datagen_block(jnp.asarray(seeds)))
    np.testing.assert_array_equal(out, np.asarray(ref.expand_ref(seeds)))


def test_verify_block_scalar_shape_and_count():
    seeds = np.arange(model.DATAGEN_BLOCK, dtype=np.uint32)
    data = np.asarray(ref.expand_ref(seeds)).copy()
    out = np.asarray(model.verify_block(jnp.asarray(seeds), jnp.asarray(data)))
    assert out.shape == (1,)
    assert out[0] == 0
    data[100, 3] ^= 0xF
    data[4000, 15] ^= 1
    out = np.asarray(model.verify_block(jnp.asarray(seeds), jnp.asarray(data)))
    assert out[0] == 2


# ------------------------------------------------------------------ bw model

def test_seq_long_burst_hits_fabric_ceiling():
    g = predict(blen=128)
    assert 5.8 <= g <= 6.4, g


def test_seq_single_addr_limited():
    g = predict(blen=1)
    assert 2.5 <= g <= 3.3, g


def test_random_single_floor():
    g = predict(blen=1, random=1.0)
    assert g < 1.2, g


def test_random_recovers_with_burst_length():
    g1 = predict(blen=1, random=1.0)
    g128 = predict(blen=128, random=1.0)
    assert g128 > 4 * g1


def test_write_random_slower_than_read_random():
    r = predict(blen=1, random=1.0, read_frac=1.0)
    w = predict(blen=1, random=1.0, read_frac=0.0)
    assert w < r, (w, r)


def test_datarate_uplift_sequential_vs_random():
    seq_up = predict(rate=2400, blen=128) / predict(rate=1600, blen=128)
    rnd_up = predict(rate=2400, blen=4, random=1.0) / predict(rate=1600, blen=4, random=1.0)
    assert seq_up > 1.35
    assert rnd_up < seq_up


def test_mixed_bounded_by_dram_bus():
    g = predict(blen=128, read_frac=0.5)
    # DDR4-1600 bus = 12.8 GB/s; mixed capped at 85% of it minus refresh
    assert g <= 12.8 * 0.85


@settings(max_examples=30, deadline=None)
@given(
    rate=st.sampled_from([1600.0, 1866.0, 2133.0, 2400.0]),
    blen=st.integers(min_value=1, max_value=128),
    random=st.sampled_from([0.0, 1.0]),
    read_frac=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_bw_model_always_positive_and_bounded(rate, blen, random, read_frac):
    g = predict(rate=rate, blen=blen, random=random, read_frac=read_frac)
    assert 0.0 < g <= 2 * 9.6 * 0.85 + 1e-3, g


@settings(max_examples=15, deadline=None)
@given(blen=st.integers(min_value=1, max_value=64))
def test_bw_model_monotone_in_burst_length(blen):
    a = predict(blen=blen, random=1.0)
    b = predict(blen=2 * blen, random=1.0)
    assert b >= a * 0.999, (blen, a, b)
