"""AOT path tests: every artifact lowers to loadable HLO text.

The real load-and-execute check lives on the Rust side
(`rust/tests/runtime_artifacts.rs`); here we assert that lowering
succeeds, the text looks like an HLO module with the expected signature,
and the build is deterministic (same source → same text), which is what
makes `make artifacts` a cacheable build step.
"""

import os

from compile import aot, model


def test_all_artifacts_lower(tmp_path):
    written = aot.build(str(tmp_path))
    assert len(written) == 3
    for path in written:
        text = open(path).read()
        assert len(text) > 1000
        assert "HloModule" in text, path
        assert "ENTRY" in text, path


def test_datagen_signature():
    text = aot.lower_datagen()
    assert f"u32[{model.DATAGEN_BLOCK}]" in text
    assert f"u32[{model.DATAGEN_BLOCK},16]" in text


def test_verify_signature():
    text = aot.lower_verify()
    assert f"u32[{model.DATAGEN_BLOCK},16]" in text
    assert "u32[1]" in text


def test_bwmodel_signature():
    text = aot.lower_bwmodel()
    assert f"f32[{model.BWMODEL_BLOCK},{model.BWMODEL_FEATURES}]" in text
    assert f"f32[{model.BWMODEL_BLOCK}]" in text


def test_lowering_deterministic():
    assert aot.lower_datagen() == aot.lower_datagen()


def test_build_into_existing_dir(tmp_path):
    d = tmp_path / "arts"
    os.makedirs(d)
    first = aot.build(str(d))
    second = aot.build(str(d))  # overwrite in place
    assert first == second
