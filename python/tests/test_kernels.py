"""L1 kernel correctness: Pallas vs the pure-jnp oracle (`ref.py`).

The hypothesis sweeps drive random shapes/seeds through both paths and
require bit-exact equality; the pinned-constant tests keep python and the
Rust mirror (`rust/src/trafficgen/payload.rs`) in lockstep.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import prbs, ref

BLOCK = prbs.BLOCK


def as_np(x):
    return np.asarray(x)


# ---------------------------------------------------------------- pinned

def test_xorshift_sequence_pinned():
    """Seed 1 must produce the canonical xorshift32 stream (same constants
    are asserted by rust/src/rng.rs::xorshift32_known_sequence)."""
    out = as_np(ref.expand_ref(np.array([1], np.uint32)))[0]
    assert out[0] == 270369
    assert out[1] == 67634689
    assert out[2] == 2647435461
    assert out[3] == 307599695


def test_burst_seed_pinned():
    """Hash constants shared with payload.rs::burst_seed_pinned_values."""
    idx = np.array([0, 1, 64], np.uint32)  # byte addrs 0, 64, 4096
    s1 = as_np(ref.burst_seed_ref(idx, 1))
    assert s1[0] == 245581154
    assert s1[1] == 3665349440
    s7 = as_np(ref.burst_seed_ref(idx, 7))
    assert s7[2] == 2593156092


def test_expand_never_zero():
    seeds = np.arange(4 * BLOCK, dtype=np.uint32)  # includes seed 0
    out = as_np(prbs.expand(jnp.asarray(seeds)))
    assert (out != 0).all(), "non-zero data requirement (paper SII-B)"


def test_zero_seed_remap_matches_ref():
    seeds = np.zeros(BLOCK, np.uint32)
    np.testing.assert_array_equal(
        as_np(prbs.expand(jnp.asarray(seeds))), as_np(ref.expand_ref(seeds))
    )


# ------------------------------------------------------------ hypothesis

@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_expand_matches_ref_random_seeds(blocks, seed):
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, 2**32, size=blocks * BLOCK, dtype=np.uint32)
    np.testing.assert_array_equal(
        as_np(prbs.expand(jnp.asarray(seeds))), as_np(ref.expand_ref(seeds))
    )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    nfaults=st.integers(min_value=0, max_value=64),
)
def test_verify_counts_planted_faults(seed, nfaults):
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, 2**32, size=BLOCK, dtype=np.uint32)
    data = as_np(ref.expand_ref(seeds)).copy()
    flat = data.reshape(-1)
    pos = rng.choice(flat.size, size=nfaults, replace=False)
    flat[pos] ^= rng.integers(1, 2**32, size=nfaults, dtype=np.uint32)
    counts = as_np(prbs.verify_counts(jnp.asarray(seeds), jnp.asarray(data)))
    assert counts.sum() == nfaults
    # and the oracle agrees
    assert int(ref.verify_ref(seeds, data)) == nfaults


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_verify_clean_is_zero(seed):
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, 2**32, size=2 * BLOCK, dtype=np.uint32)
    data = ref.expand_ref(seeds)
    counts = as_np(prbs.verify_counts(jnp.asarray(seeds), data))
    assert counts.sum() == 0


@settings(max_examples=10, deadline=None)
@given(
    pattern_seed=st.integers(min_value=0, max_value=2**32 - 1),
    n=st.integers(min_value=1, max_value=512),
)
def test_burst_seed_nonzero_and_distinct(pattern_seed, n):
    idx = np.arange(n, dtype=np.uint32)
    seeds = as_np(ref.burst_seed_ref(idx, pattern_seed))
    assert (seeds != 0).all()
    # the mix should not collide over small consecutive index ranges
    assert len(np.unique(seeds)) == n


# ----------------------------------------------------------- shape guard

def test_expand_rejects_non_multiple_of_block():
    with pytest.raises(AssertionError):
        prbs.expand(jnp.zeros(BLOCK + 1, jnp.uint32))


def test_verify_rejects_shape_mismatch():
    with pytest.raises(AssertionError):
        prbs.verify_counts(
            jnp.zeros(BLOCK, jnp.uint32), jnp.zeros((BLOCK, 15), jnp.uint32)
        )
