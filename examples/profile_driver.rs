//! Profiling driver for the §Perf pass (EXPERIMENTS.md): runs a fixed
//! mix of saturated sequential bursts and random singles so
//! `perf record -g ./target/release/examples/profile_driver` captures a
//! representative hot-path distribution without bench-harness noise.

use ddr4bench::config::{DesignConfig, PatternConfig, SpeedBin};
use ddr4bench::platform::Platform;

fn main() {
    let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    for _ in 0..12 {
        let s = p.run_batch(0, &PatternConfig::seq_read_burst(32, 4096)).unwrap();
        std::hint::black_box(s.read_throughput_gbs());
        let s = p.run_batch(0, &PatternConfig::rnd_read_burst(1, 4096, 3)).unwrap();
        std::hint::black_box(s.read_throughput_gbs());
    }
}
