//! Quickstart: instantiate the platform, run a handful of traffic
//! patterns, and print the statistics a host PC would collect.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT XLA artifacts when present (`make artifacts`) so payload
//! generation/verification run through PJRT; falls back to the pure-Rust
//! mirror otherwise.

use ddr4bench::config::{AddrMode, DesignConfig, PatternConfig, SpeedBin};
use ddr4bench::platform::Platform;
use ddr4bench::runtime::XlaRuntime;

fn main() -> anyhow::Result<()> {
    // Design time: one channel of DDR4-1600 (PHY 800 MHz / AXI 200 MHz).
    let design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
    let mut platform = Platform::new(design);

    let dir = ddr4bench::artifacts_dir();
    if XlaRuntime::artifacts_present(&dir) {
        let rt = XlaRuntime::load(&dir)?;
        println!("XLA runtime loaded ({})\n", rt.platform());
        platform = platform.with_runtime(rt);
    } else {
        println!("(artifacts not built; using the pure-Rust data path)\n");
    }

    // Run time: a few representative patterns, all reconfigured on the
    // fly — no "resynthesis" needed (the paper's Table I split).
    let patterns: Vec<(&str, PatternConfig)> = vec![
        ("sequential read, medium bursts (32)", PatternConfig::seq_read_burst(32, 4096)),
        ("sequential write, medium bursts (32)", PatternConfig::seq_write_burst(32, 4096)),
        ("random read, single transactions", PatternConfig::rnd_read_burst(1, 2048, 7)),
        ("random write, short bursts (4)", PatternConfig::rnd_write_burst(4, 2048, 7)),
        ("mixed 50/50, sequential, long bursts (128)",
         PatternConfig::mixed(AddrMode::Sequential, 128, 1024)),
    ];

    println!(
        "{:<46} {:>8} {:>8} {:>8} {:>10}",
        "pattern", "rd GB/s", "wr GB/s", "total", "lat (ns)"
    );
    for (name, cfg) in &patterns {
        let stats = platform.run_batch(0, cfg)?;
        println!(
            "{:<46} {:>8.2} {:>8.2} {:>8.2} {:>10.0}",
            name,
            stats.read_throughput_gbs(),
            stats.write_throughput_gbs(),
            stats.total_throughput_gbs(),
            stats.read_latency_ns().max(stats.write_latency_ns()),
        );
    }

    // Data integrity (the paper's differentiator vs. Shuhai): write a
    // region with PRBS payloads, read it back, verify.
    println!("\ndata integrity check:");
    let region = 1024 * 4 * 32;
    let mut w = PatternConfig::seq_write_burst(4, 1024);
    w.verify = true;
    w.region_bytes = region;
    platform.run_batch(0, &w)?;
    let mut r = PatternConfig::seq_read_burst(4, 1024);
    r.verify = true;
    r.region_bytes = region;
    let clean = platform.run_batch(0, &r)?;
    println!("  clean read-back:    {} mismatches", clean.counters.mismatches);
    platform.corrupt(0, 128, 3, 0x1);
    let dirty = platform.run_batch(0, &r)?;
    println!("  after fault inject: {} mismatches (detected)", dirty.counters.mismatches);
    assert_eq!(clean.counters.mismatches, 0);
    assert!(dirty.counters.mismatches > 0);
    Ok(())
}
