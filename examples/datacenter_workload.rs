//! Data-center workload replay — the paper's motivating scenario (§I):
//! an ML inference server's memory traffic, phrased as the traffic
//! patterns its phases actually generate, replayed through the
//! benchmarking platform on a triple-channel DDR4-2400 design.
//!
//! ```text
//! cargo run --release --example datacenter_workload
//! ```
//!
//! Phases (one TG batch each, channels running concurrently):
//!
//! 1. **model load** — streaming the weights in: long sequential writes;
//! 2. **weight streaming** — per-inference weight reads: long sequential
//!    read bursts (the dominant traffic of dense layers);
//! 3. **KV-cache / embedding lookups** — random medium-burst mixed
//!    read/write traffic (70% reads);
//! 4. **request/response logging** — short sequential writes;
//! 5. **integrity audit** — random verified read-back over the written
//!    footprint (memory scrubbing).
//!
//! The report gives per-phase bandwidth, latency and the derived
//! tokens/s-style headline (bytes per inference step / achieved GB/s).

use ddr4bench::config::{AddrMode, DesignConfig, OpMix, PatternConfig, SpeedBin};
use ddr4bench::platform::Platform;
use ddr4bench::report::Table;
use ddr4bench::runtime::XlaRuntime;

struct Phase {
    name: &'static str,
    cfg: PatternConfig,
}

fn phases() -> Vec<Phase> {
    let mut model_load = PatternConfig::seq_write_burst(128, 1536);
    model_load.verify = true;
    model_load.region_bytes = 768 << 20;

    let mut weight_stream = PatternConfig::seq_read_burst(128, 2048);
    weight_stream.region_bytes = 768 << 20;

    let mut kv_cache = PatternConfig::mixed(AddrMode::Random { seed: 0xFEED }, 16, 4096);
    kv_cache.op = OpMix::Mixed { read_pct: 70 };
    kv_cache.region_bytes = 64 << 20;

    let mut logging = PatternConfig::seq_write_burst(4, 4096);
    logging.start_addr = 1 << 30;
    logging.region_bytes = 16 << 20;

    let mut audit = PatternConfig::rnd_read_burst(128, 1024, 0xA0D1);
    audit.verify = true;
    audit.region_bytes = 768 << 20;

    vec![
        Phase { name: "model load (seq W, LB)", cfg: model_load },
        Phase { name: "weight streaming (seq R, LB)", cfg: weight_stream },
        Phase { name: "KV-cache lookups (rnd M 70/30, 16)", cfg: kv_cache },
        Phase { name: "logging (seq W, SB)", cfg: logging },
        Phase { name: "integrity audit (rnd R, LB, verify)", cfg: audit },
    ]
}

fn main() -> anyhow::Result<()> {
    let design = DesignConfig::with_channels(3, SpeedBin::Ddr4_2400);
    let mut platform = Platform::new(design);
    let dir = ddr4bench::artifacts_dir();
    if XlaRuntime::artifacts_present(&dir) {
        platform = platform.with_runtime(XlaRuntime::load(&dir)?);
        println!("XLA data path active (payloads + verification via PJRT)\n");
    }

    let mut t = Table::new(
        "ML inference server memory-traffic replay (3x DDR4-2400 channels)",
        &["Phase", "GB moved", "GB/s", "avg lat (ns)", "sim time (ms)", "mismatches"],
    );
    let mut total_bytes = 0u64;
    let mut total_time_s = 0.0f64;
    for phase in phases() {
        let per = platform.run_batch_all(&phase.cfg)?;
        let agg = Platform::aggregate(&per);
        let bytes = agg.counters.rd_bytes + agg.counters.wr_bytes;
        let gbs = agg.total_throughput_gbs();
        let time_ms = bytes as f64 / gbs / 1e6;
        total_bytes += bytes;
        total_time_s += time_ms / 1e3;
        t.row(vec![
            phase.name.to_string(),
            format!("{:.3}", bytes as f64 / 1e9),
            format!("{gbs:.2}"),
            format!("{:.0}", agg.read_latency_ns().max(agg.write_latency_ns())),
            format!("{time_ms:.3}"),
            agg.counters.mismatches.to_string(),
        ]);
    }
    println!("{}", t.ascii());

    // Headline: with ~100 MB of weight traffic per inference step, the
    // achieved bandwidth translates to this many steps per second.
    let eff_gbs = total_bytes as f64 / 1e9 / total_time_s;
    println!("workload aggregate: {:.2} GB in {:.1} ms -> {eff_gbs:.2} GB/s effective",
             total_bytes as f64 / 1e9, total_time_s * 1e3);
    println!("at 100 MB weight traffic per step: {:.0} inference steps/s", eff_gbs * 10.0);
    Ok(())
}
