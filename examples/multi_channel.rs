//! Design-space exploration across the platform's design-time axes:
//! channel count (1–3, the XCKU115 limit) × memory data rate (the four
//! JEDEC bins) — the "flexible memory setup" contribution of the paper.
//!
//! ```text
//! cargo run --release --example multi_channel
//! ```
//!
//! For every design point the example instantiates a fresh platform,
//! runs the best-case pattern (sequential medium-burst reads) plus a
//! mixed workload on all channels concurrently, and reports aggregate
//! throughput and the modeled FPGA resource cost — the throughput/area
//! trade-off a deployment would weigh.

use ddr4bench::config::{AddrMode, DesignConfig, PatternConfig, SpeedBin};
use ddr4bench::platform::Platform;
use ddr4bench::report::Table;
use ddr4bench::resource;

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(
        "Design-space exploration: channels x data rate",
        &[
            "Channels",
            "Data rate",
            "Seq-R GB/s",
            "Mixed GB/s",
            "LUT",
            "BRAM",
            "GB/s per kLUT",
        ],
    );
    for channels in 1..=3usize {
        for speed in SpeedBin::ALL {
            let design = DesignConfig::with_channels(channels, speed);
            let cost = resource::design_cost(&design);
            let mut platform = Platform::new(design);

            let read = PatternConfig::seq_read_burst(32, 2048);
            let per = platform.run_batch_all(&read)?;
            let seq_r = Platform::aggregate(&per).read_throughput_gbs();

            let mixed = PatternConfig::mixed(AddrMode::Sequential, 128, 1024);
            let per = platform.run_batch_all(&mixed)?;
            let mix = Platform::aggregate(&per).total_throughput_gbs();

            t.row(vec![
                channels.to_string(),
                speed.to_string(),
                format!("{seq_r:.2}"),
                format!("{mix:.2}"),
                format!("{:.0}", cost.lut),
                format!("{}", cost.bram),
                format!("{:.3}", seq_r / (cost.lut / 1000.0)),
            ]);
        }
    }
    println!("{}", t.ascii());

    // Sanity: the paper's scaling claim — triple channel = 3x single.
    let single = Platform::new(DesignConfig::with_channels(1, SpeedBin::Ddr4_2400))
        .run_batch_all(&PatternConfig::seq_read_burst(32, 2048))?;
    let triple = Platform::new(DesignConfig::with_channels(3, SpeedBin::Ddr4_2400))
        .run_batch_all(&PatternConfig::seq_read_burst(32, 2048))?;
    let s = Platform::aggregate(&single).read_throughput_gbs();
    let tr = Platform::aggregate(&triple).read_throughput_gbs();
    println!("triple/single @ DDR4-2400: {:.2}x (paper: 3x)", tr / s);
    Ok(())
}
