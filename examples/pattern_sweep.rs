//! Access-pattern engine showcase + parallel campaign sweep.
//!
//! Part 1 runs each of the engine's address modes on one platform and
//! prints the throughput ladder they produce (sequential fastest, the
//! dependent pointer chase slowest). Part 2 hands the full Fig. 2
//! data-rate grid (2 speeds × 2 channel counts × 3 adversarial patterns
//! = 12 jobs) to the work-stealing sweep executive and prints its
//! summary table.
//!
//! ```text
//! cargo run --release --example pattern_sweep
//! cargo run --release --example pattern_sweep -- --write  # also emit sweep-out/
//! ```

use ddr4bench::config::{AddrMode, DesignConfig, PatternConfig, SpeedBin};
use ddr4bench::platform::sweep::{run_sweep, summary_table, write_artifacts, SweepSpec};
use ddr4bench::platform::Platform;

fn main() -> anyhow::Result<()> {
    // --- part 1: the pattern ladder on a single DDR4-1600 channel -------
    let mut platform = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    let batch = 1024;
    let patterns: Vec<(&str, PatternConfig)> = vec![
        ("sequential singles", PatternConfig::seq_read_burst(1, batch)),
        ("strided (one row, 64 KiB)", PatternConfig::strided_read(64 << 10, 1, batch)),
        ("uniform random", PatternConfig::rnd_read_burst(1, batch, 0xF00D)),
        ("bank conflict", PatternConfig::bank_conflict_read(1, batch, 1)),
        ("pointer chase (dependent)", PatternConfig::pointer_chase_read(4 << 20, batch, 7)),
        ("phased seq->rnd", {
            let mut p = PatternConfig::seq_read_burst(1, batch);
            p.addr = AddrMode::Phased(vec![
                (AddrMode::Sequential, 256),
                (AddrMode::Random { seed: 0xF00D }, 256),
            ]);
            p
        }),
    ];
    println!("pattern ladder (single-channel DDR4-1600, single-beat reads):");
    for (name, cfg) in &patterns {
        let s = platform.run_batch(0, cfg)?;
        println!(
            "  {name:<28} {:>6.2} GB/s  (mean latency {:>6.0} ns)",
            s.read_throughput_gbs(),
            s.read_latency_ns()
        );
    }

    // --- part 2: the parallel campaign sweep ----------------------------
    let spec = SweepSpec::paper_grid();
    let jobs = spec.expand();
    println!(
        "\nsweep: {} jobs ({:?} x {:?} channels x {} patterns)",
        jobs.len(),
        spec.speeds.iter().map(|s| s.data_rate_mts()).collect::<Vec<_>>(),
        spec.channels,
        spec.patterns.len()
    );
    let outcomes = run_sweep(jobs, 4)?;
    println!("{}", summary_table(&outcomes).ascii());

    if std::env::args().any(|a| a == "--write") {
        let summary = write_artifacts(&outcomes, std::path::Path::new("sweep-out"))?;
        println!("artifacts written; summary at {}", summary.display());
    }
    Ok(())
}
