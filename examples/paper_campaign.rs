//! The paper's full experimental campaign, end to end: regenerates every
//! table and figure of the evaluation section and writes the CSVs.
//!
//! ```text
//! cargo run --release --example paper_campaign             # full scale
//! cargo run --release --example paper_campaign -- --scale 0.2
//! cargo run --release --example paper_campaign -- --only table4,fig2
//! ```
//!
//! This is the end-to-end driver recorded in EXPERIMENTS.md: it exercises
//! the whole stack (host-controller-style batch executive → traffic
//! generators → memory controller → DDR4 device model, with the XLA data
//! path when artifacts exist) on the paper's workload grid and reports
//! the paper's headline metric (throughput in GB/s per configuration).

use ddr4bench::cli::Cli;
use ddr4bench::report::{campaign, Table};
use ddr4bench::resource;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("paper_campaign", "regenerate every paper table/figure")
        .option("scale", "campaign scale factor (default 1.0)")
        .option("only", "comma subset: table3,table4,fig2,fig3,scaling,analysis,modelcheck")
        .option("outdir", "CSV output directory (default results)");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(help) => {
            println!("{help}");
            return Ok(());
        }
    };
    let scale: f64 = args.parse_or("scale", 1.0).map_err(anyhow::Error::msg)?;
    let outdir = std::path::PathBuf::from(args.get_or("outdir", "results"));
    std::fs::create_dir_all(&outdir)?;
    let only: Option<Vec<String>> =
        args.get("only").map(|s| s.split(',').map(|x| x.trim().to_string()).collect());
    let want = |name: &str| only.as_ref().map_or(true, |v| v.iter().any(|x| x == name));
    let t0 = std::time::Instant::now();

    if want("table3") {
        let mut t = Table::new(
            "Table III: FPGA resource utilization (modeled)",
            &["Component/Design", "LUT", "FF", "BRAM", "DSP"],
        );
        for row in resource::table3() {
            t.row(vec![
                row.name,
                format!("{:.0}", row.res.lut),
                format!("{:.0}", row.res.ff),
                format!("{}", row.res.bram),
                format!("{:.0}", row.res.dsp),
            ]);
        }
        println!("{}", t.ascii());
        t.write_csv(&outdir.join("table3.csv"))?;
    }

    if want("table4") {
        let (t, _) = campaign::table4(scale);
        println!("{}", t.ascii());
        t.write_csv(&outdir.join("table4.csv"))?;
    }

    if want("fig2") {
        for (i, fig) in campaign::fig2(scale).into_iter().enumerate() {
            println!("{}", fig.ascii());
            std::fs::write(
                outdir.join(format!("fig2_{}.csv", if i == 0 { "1600" } else { "2400" })),
                fig.csv(),
            )?;
        }
    }

    if want("fig3") {
        let t = campaign::fig3(scale);
        println!("{}", t.ascii());
        t.write_csv(&outdir.join("fig3.csv"))?;
    }

    if want("scaling") {
        let t = campaign::scaling(scale);
        println!("{}", t.ascii());
        t.write_csv(&outdir.join("scaling.csv"))?;
    }

    if want("analysis") {
        let t = campaign::analysis(scale);
        println!("{}", t.ascii());
        t.write_csv(&outdir.join("analysis.csv"))?;
    }

    if want("modelcheck") {
        let (t, mae) = campaign::model_check(scale);
        println!("{}", t.ascii());
        println!("analytic-model mean absolute relative error vs simulator: {:.1}%\n", mae * 100.0);
        t.write_csv(&outdir.join("modelcheck.csv"))?;
    }

    println!("campaign done in {:.1}s; CSVs in {}", t0.elapsed().as_secs_f64(), outdir.display());
    Ok(())
}
