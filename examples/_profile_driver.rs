// standalone profile driver: run many batches
use ddr4bench::config::{DesignConfig, PatternConfig, SpeedBin};
use ddr4bench::platform::Platform;
fn main() {
    let mut p = Platform::new(DesignConfig::single_channel(SpeedBin::Ddr4_1600));
    for _ in 0..12 {
        let s = p.run_batch(0, &PatternConfig::seq_read_burst(32, 4096)).unwrap();
        std::hint::black_box(s.read_throughput_gbs());
        let s = p.run_batch(0, &PatternConfig::rnd_read_burst(1, 4096, 3)).unwrap();
        std::hint::black_box(s.read_throughput_gbs());
    }
}
