//! Interactive host-controller session (§II-C): drives the platform the
//! exact way the paper's host PC does over UART — configuration commands
//! in, statistics out.
//!
//! ```text
//! cargo run --release --example host_session                 # scripted session
//! cargo run --release --example host_session -- --tcp 127.0.0.1:5557
//! ```
//!
//! In scripted mode the example replays a benchmarking session over the
//! in-memory UART and prints the transcript; with `--tcp` it serves one
//! real session (`nc 127.0.0.1 5557`, then type `HELP`).

use ddr4bench::config::{DesignConfig, SpeedBin};
use ddr4bench::hostctrl::{serve_tcp, HostController};
use ddr4bench::platform::Platform;

const SCRIPT: &[&str] = &[
    "HELP",
    "INFO",
    // channel 0: sequential medium-burst reads
    "CFG 0 OP=R ADDR=SEQ BURST=32 TYPE=INCR SIG=NB BATCH=4096",
    "RUN 0",
    "STATS 0",
    // reconfigure at run time: random single-transaction writes
    "CFG 0 OP=W ADDR=RND SEED=42 BURST=1 BATCH=2048",
    "RUN 0",
    "STATS 0",
    // mixed workload with verification on
    "CFG 0 OP=M RDPCT=50 ADDR=SEQ BURST=128 BATCH=1024 VERIFY=1",
    "RUN 0",
    "STATS 0",
    "RESET 0",
    "QUIT",
];

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let design = DesignConfig::single_channel(SpeedBin::Ddr4_1600);
    let host = HostController::new(Platform::new(design));

    if let Some(pos) = args.iter().position(|a| a == "--tcp") {
        let addr = args.get(pos + 1).map(String::as_str).unwrap_or("127.0.0.1:5557");
        println!("serving one host session on {addr} (connect with `nc`)");
        serve_tcp(host, addr, Some(1))?;
        return Ok(());
    }

    // Scripted UART session: feed the command lines through the same
    // serve() loop a serial link would drive.
    let mut host = host;
    let input = SCRIPT.join("\n") + "\n";
    let mut output = Vec::new();
    host.serve(std::io::Cursor::new(input.into_bytes()), &mut output)?;
    let transcript = String::from_utf8(output)?;
    for (cmd, resp) in SCRIPT.iter().zip(transcript.lines()) {
        println!("> {cmd}");
        println!("< {resp}\n");
    }
    Ok(())
}
